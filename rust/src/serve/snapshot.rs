//! The versioned serve checkpoint codec.
//!
//! A [`ServeSnapshot`] is the complete logical state of a running serve
//! daemon at a monitoring-interval boundary, as one JSON document:
//!
//! - the [`ServeSpec`] (rebuild-time constants: scenario, hosts, seed…),
//! - the **admission replay log** — every lane admitted so far, with its
//!   *resolved* method seed and name, so `--restore` can replay the exact
//!   admission sequence and regenerate flows, arena rows and ledger
//!   accounts,
//! - the **pending op queue** — admissions/pauses/resumes/cancels not yet
//!   due (the snapshot is captured *before* the ops due at its MI are
//!   applied, so the restored run applies them itself),
//! - the fleet's captured mutable state ([`FleetState`]).
//!
//! Bit-exactness: the repo's [`Json`] printer renders numbers through
//! decimal formatting, which does not round-trip every `f64`. The codec
//! therefore encodes every float as its IEEE-754 bit pattern in fixed-width
//! hex (`f64` → 16 hex digits, `f32` → 8) and every `u64` (seeds, RNG
//! words) as a decimal string. Restored state is therefore *identical*,
//! not merely close — which is what makes the resumed event stream
//! byte-identical to an uninterrupted run's.

use super::{FleetState, ServeSpec};
use crate::coordinator::{
    ClusterState, LaneState, LaneStatus, SessionState, TrackerState, WindowState,
};
use crate::energy::{AccountState, LedgerState, RailEnergy};
use crate::net::sim::{FlowState, SegmentState};
use crate::net::stream::ArenaState;
use crate::net::SimState;
use crate::util::json::Json;
use anyhow::{anyhow, Result};
use std::path::Path;

/// Bumped on any incompatible change to the snapshot document layout.
pub const SNAPSHOT_VERSION: usize = 1;

/// One admission, as queued (unresolved `seed`/`name`) or as replayed
/// (both resolved at execution time and recorded in the admission log).
#[derive(Debug, Clone, PartialEq)]
pub struct AdmitRec {
    /// Method name for [`crate::experiments::make_optimizer`].
    pub method: String,
    /// Workload: `files` × `file_bytes`.
    pub files: usize,
    pub file_bytes: u64,
    /// Lane name; `None` defaults to `{method}#{admission index}`.
    pub name: Option<String>,
    /// Optimizer seed; `None` derives from (serve seed, method, index).
    pub seed: Option<u64>,
    /// Forced cancel this many MIs after admission, if still running.
    pub max_lifetime_mis: Option<usize>,
}

/// A control operation waiting in the serve queue.
#[derive(Debug, Clone, PartialEq)]
pub enum OpKind {
    Admit(AdmitRec),
    Pause(usize),
    Resume(usize),
    Cancel(usize),
}

/// An [`OpKind`] plus the MI boundary at which it becomes due.
#[derive(Debug, Clone, PartialEq)]
pub struct PendingOp {
    pub at_mi: usize,
    pub op: OpKind,
}

/// A complete serve checkpoint (see module docs).
#[derive(Debug, Clone, PartialEq)]
pub struct ServeSnapshot {
    pub spec: ServeSpec,
    /// Admissions already executed, resolved, in admission order.
    pub admits: Vec<AdmitRec>,
    /// Ops not yet applied (includes everything due at the capture MI).
    pub queue: Vec<PendingOp>,
    pub state: FleetState,
}

impl ServeSnapshot {
    pub fn to_json(&self) -> Json {
        Json::obj(vec![
            ("version", Json::from(SNAPSHOT_VERSION)),
            ("spec", spec_json(&self.spec)),
            ("admits", Json::Arr(self.admits.iter().map(admit_json).collect())),
            ("queue", Json::Arr(self.queue.iter().map(op_json).collect())),
            ("state", fleet_json(&self.state)),
        ])
    }

    pub fn from_json(j: &Json) -> Result<ServeSnapshot> {
        let version = gusize(field(j, "version")?, "version")?;
        if version != SNAPSHOT_VERSION {
            return Err(anyhow!(
                "snapshot version {version} not supported (this build reads {SNAPSHOT_VERSION})"
            ));
        }
        Ok(ServeSnapshot {
            spec: gspec(field(j, "spec")?)?,
            admits: gadmits(field(j, "admits")?)?,
            queue: gops(field(j, "queue")?)?,
            state: gfleet(field(j, "state")?)?,
        })
    }

    pub fn save(&self, path: &Path) -> Result<()> {
        std::fs::write(path, format!("{}\n", self.to_json()))
            .map_err(|e| anyhow!("writing snapshot {}: {e}", path.display()))
    }

    pub fn load(path: &Path) -> Result<ServeSnapshot> {
        let text = std::fs::read_to_string(path)
            .map_err(|e| anyhow!("reading snapshot {}: {e}", path.display()))?;
        let j = Json::parse(&text).map_err(|e| anyhow!("snapshot {}: {e}", path.display()))?;
        ServeSnapshot::from_json(&j)
    }
}

/// Canonical wire names for [`LaneStatus`] (also used by `status` replies).
pub fn status_str(s: LaneStatus) -> &'static str {
    match s {
        LaneStatus::Active => "active",
        LaneStatus::Paused => "paused",
        // Never serialized into snapshots (faulted services refuse to
        // checkpoint) but `status` replies report it live.
        LaneStatus::Faulted => "faulted",
        LaneStatus::Completed => "completed",
        LaneStatus::Departed => "departed",
    }
}

fn status_from(s: &str) -> Result<LaneStatus> {
    match s {
        "active" => Ok(LaneStatus::Active),
        "paused" => Ok(LaneStatus::Paused),
        "faulted" => Ok(LaneStatus::Faulted),
        "completed" => Ok(LaneStatus::Completed),
        "departed" => Ok(LaneStatus::Departed),
        other => Err(anyhow!("snapshot: unknown lane status '{other}'")),
    }
}

// ---------------------------------------------------------------------------
// Primitive codec: bit-pattern floats, string u64s.

fn jf64(x: f64) -> Json {
    Json::Str(format!("{:016x}", x.to_bits()))
}

fn jf32(x: f32) -> Json {
    Json::Str(format!("{:08x}", x.to_bits()))
}

fn ju64(x: u64) -> Json {
    Json::Str(x.to_string())
}

fn jf64s(xs: &[f64]) -> Json {
    Json::Arr(xs.iter().map(|&x| jf64(x)).collect())
}

fn jbools(xs: &[bool]) -> Json {
    Json::Arr(xs.iter().map(|&b| Json::from(b)).collect())
}

fn jopt<T: Copy>(x: Option<T>, f: impl Fn(T) -> Json) -> Json {
    match x {
        Some(v) => f(v),
        None => Json::Null,
    }
}

fn jrng(r: &[u64; 4]) -> Json {
    Json::Arr(r.iter().map(|&w| ju64(w)).collect())
}

fn field<'a>(j: &'a Json, k: &str) -> Result<&'a Json> {
    j.get(k).ok_or_else(|| anyhow!("snapshot: missing field '{k}'"))
}

fn gstr(j: &Json, what: &str) -> Result<String> {
    j.as_str().map(str::to_string).ok_or_else(|| anyhow!("snapshot: {what} must be a string"))
}

fn gbool(j: &Json, what: &str) -> Result<bool> {
    j.as_bool().ok_or_else(|| anyhow!("snapshot: {what} must be a bool"))
}

fn gusize(j: &Json, what: &str) -> Result<usize> {
    j.as_usize().ok_or_else(|| anyhow!("snapshot: {what} must be a non-negative integer"))
}

fn gu64(j: &Json, what: &str) -> Result<u64> {
    let s = j.as_str().ok_or_else(|| anyhow!("snapshot: {what} must be a decimal u64 string"))?;
    s.parse::<u64>().map_err(|_| anyhow!("snapshot: {what}: bad u64 '{s}'"))
}

fn gf64(j: &Json, what: &str) -> Result<f64> {
    let s = j.as_str().ok_or_else(|| anyhow!("snapshot: {what} must be a hex f64 string"))?;
    let bits = u64::from_str_radix(s, 16)
        .map_err(|_| anyhow!("snapshot: {what}: bad f64 bit pattern '{s}'"))?;
    Ok(f64::from_bits(bits))
}

fn gf32(j: &Json, what: &str) -> Result<f32> {
    let s = j.as_str().ok_or_else(|| anyhow!("snapshot: {what} must be a hex f32 string"))?;
    let bits = u32::from_str_radix(s, 16)
        .map_err(|_| anyhow!("snapshot: {what}: bad f32 bit pattern '{s}'"))?;
    Ok(f32::from_bits(bits))
}

fn garr<'a>(j: &'a Json, what: &str) -> Result<&'a [Json]> {
    j.as_arr().ok_or_else(|| anyhow!("snapshot: {what} must be an array"))
}

fn gf64s(j: &Json, what: &str) -> Result<Vec<f64>> {
    garr(j, what)?.iter().map(|x| gf64(x, what)).collect()
}

fn gbools(j: &Json, what: &str) -> Result<Vec<bool>> {
    garr(j, what)?.iter().map(|x| gbool(x, what)).collect()
}

fn gopt<T>(j: &Json, f: impl Fn(&Json) -> Result<T>) -> Result<Option<T>> {
    match j {
        Json::Null => Ok(None),
        other => f(other).map(Some),
    }
}

fn grng(j: &Json, what: &str) -> Result<[u64; 4]> {
    let words = garr(j, what)?;
    if words.len() != 4 {
        return Err(anyhow!("snapshot: {what} must hold 4 RNG words"));
    }
    let mut out = [0u64; 4];
    for (slot, w) in out.iter_mut().zip(words) {
        *slot = gu64(w, what)?;
    }
    Ok(out)
}

// ---------------------------------------------------------------------------
// Spec / ops.

fn spec_json(s: &ServeSpec) -> Json {
    let mut o = vec![
        ("scenario", Json::from(s.scenario.as_str())),
        ("schedule", jopt(s.schedule.as_deref(), Json::from)),
        ("methods", Json::Arr(s.methods.iter().map(|m| Json::from(m.as_str())).collect())),
        ("hosts", Json::from(s.hosts)),
        ("seed", ju64(s.seed)),
        ("mi_s", jf64(s.mi_s)),
        ("max_mis", Json::from(s.max_mis)),
        ("observe_paused", Json::from(s.observe_paused)),
    ];
    // Written only when set, so fault-free snapshots stay byte-identical
    // to the pre-fault-plane format. (In practice a faulted service never
    // snapshots — its fleet refuses to export — but the spec rides along
    // in `status` replies too.)
    if let Some(f) = &s.faults {
        o.push(("faults", Json::from(f.as_str())));
    }
    Json::obj(o)
}

fn gspec(j: &Json) -> Result<ServeSpec> {
    Ok(ServeSpec {
        scenario: gstr(field(j, "scenario")?, "spec.scenario")?,
        schedule: gopt(field(j, "schedule")?, |x| gstr(x, "spec.schedule"))?,
        methods: garr(field(j, "methods")?, "spec.methods")?
            .iter()
            .map(|m| gstr(m, "spec.methods"))
            .collect::<Result<Vec<_>>>()?,
        hosts: gusize(field(j, "hosts")?, "spec.hosts")?,
        seed: gu64(field(j, "seed")?, "spec.seed")?,
        mi_s: gf64(field(j, "mi_s")?, "spec.mi_s")?,
        max_mis: gusize(field(j, "max_mis")?, "spec.max_mis")?,
        observe_paused: gbool(field(j, "observe_paused")?, "spec.observe_paused")?,
        // Absent in pre-fault-plane snapshots: tolerant read.
        faults: match j.get("faults") {
            Some(f) => gopt(f, |x| gstr(x, "spec.faults"))?,
            None => None,
        },
    })
}

fn gadmits(j: &Json) -> Result<Vec<AdmitRec>> {
    garr(j, "admits")?.iter().map(gadmit).collect()
}

fn gops(j: &Json) -> Result<Vec<PendingOp>> {
    garr(j, "queue")?.iter().map(gop).collect()
}

fn admit_json(a: &AdmitRec) -> Json {
    Json::obj(vec![
        ("method", Json::from(a.method.as_str())),
        ("files", Json::from(a.files)),
        ("file_bytes", ju64(a.file_bytes)),
        ("name", jopt(a.name.as_deref(), Json::from)),
        ("seed", jopt(a.seed, ju64)),
        ("max_lifetime_mis", jopt(a.max_lifetime_mis, Json::from)),
    ])
}

fn gadmit(j: &Json) -> Result<AdmitRec> {
    Ok(AdmitRec {
        method: gstr(field(j, "method")?, "admit.method")?,
        files: gusize(field(j, "files")?, "admit.files")?,
        file_bytes: gu64(field(j, "file_bytes")?, "admit.file_bytes")?,
        name: gopt(field(j, "name")?, |x| gstr(x, "admit.name"))?,
        seed: gopt(field(j, "seed")?, |x| gu64(x, "admit.seed"))?,
        max_lifetime_mis: gopt(field(j, "max_lifetime_mis")?, |x| {
            gusize(x, "admit.max_lifetime_mis")
        })?,
    })
}

fn op_json(p: &PendingOp) -> Json {
    let mut fields = vec![("at_mi", Json::from(p.at_mi))];
    match &p.op {
        OpKind::Admit(a) => {
            fields.push(("kind", Json::from("admit")));
            fields.push(("admit", admit_json(a)));
        }
        OpKind::Pause(l) => {
            fields.push(("kind", Json::from("pause")));
            fields.push(("lane", Json::from(*l)));
        }
        OpKind::Resume(l) => {
            fields.push(("kind", Json::from("resume")));
            fields.push(("lane", Json::from(*l)));
        }
        OpKind::Cancel(l) => {
            fields.push(("kind", Json::from("cancel")));
            fields.push(("lane", Json::from(*l)));
        }
    }
    Json::obj(fields)
}

fn gop(j: &Json) -> Result<PendingOp> {
    let at_mi = gusize(field(j, "at_mi")?, "op.at_mi")?;
    let kind = gstr(field(j, "kind")?, "op.kind")?;
    let op = match kind.as_str() {
        "admit" => OpKind::Admit(gadmit(field(j, "admit")?)?),
        "pause" => OpKind::Pause(gusize(field(j, "lane")?, "op.lane")?),
        "resume" => OpKind::Resume(gusize(field(j, "lane")?, "op.lane")?),
        "cancel" => OpKind::Cancel(gusize(field(j, "lane")?, "op.lane")?),
        other => return Err(anyhow!("snapshot: unknown op kind '{other}'")),
    };
    Ok(PendingOp { at_mi, op })
}

// ---------------------------------------------------------------------------
// Fleet state.

fn fleet_json(f: &FleetState) -> Json {
    match f {
        FleetState::Single(s) => Json::obj(vec![
            ("kind", Json::from("single")),
            ("session", session_json(s)),
        ]),
        FleetState::Cluster(c) => Json::obj(vec![
            ("kind", Json::from("cluster")),
            ("mi", Json::from(c.mi)),
            ("hosts", Json::Arr(c.hosts.iter().map(session_json).collect())),
        ]),
    }
}

fn gfleet(j: &Json) -> Result<FleetState> {
    match gstr(field(j, "kind")?, "state.kind")?.as_str() {
        "single" => Ok(FleetState::Single(Box::new(gsession(field(j, "session")?)?))),
        "cluster" => Ok(FleetState::Cluster(ClusterState {
            mi: gusize(field(j, "mi")?, "state.mi")?,
            hosts: garr(field(j, "hosts")?, "state.hosts")?
                .iter()
                .map(gsession)
                .collect::<Result<Vec<_>>>()?,
        })),
        other => Err(anyhow!("snapshot: unknown fleet kind '{other}'")),
    }
}

fn session_json(s: &SessionState) -> Json {
    Json::obj(vec![
        ("mi", Json::from(s.mi)),
        ("lanes", Json::Arr(s.lanes.iter().map(lane_json).collect())),
        ("energy", Json::Arr(s.energy.iter().map(ledger_json).collect())),
        ("sim", sim_json(&s.sim)),
    ])
}

fn gsession(j: &Json) -> Result<SessionState> {
    Ok(SessionState {
        mi: gusize(field(j, "mi")?, "session.mi")?,
        lanes: garr(field(j, "lanes")?, "session.lanes")?
            .iter()
            .map(glane)
            .collect::<Result<Vec<_>>>()?,
        energy: garr(field(j, "energy")?, "session.energy")?
            .iter()
            .map(gledger)
            .collect::<Result<Vec<_>>>()?,
        sim: gsim(field(j, "sim")?)?,
    })
}

fn lane_json(l: &LaneState) -> Json {
    Json::obj(vec![
        ("status", Json::from(status_str(l.status))),
        ("cc", Json::from(l.cc as usize)),
        ("p", Json::from(l.p as usize)),
        ("has_pending_decision", Json::from(l.has_pending_decision)),
        ("delivered_bytes", jf64(l.delivered_bytes)),
        ("window", window_json(&l.window)),
        ("reward", tracker_json(&l.reward)),
        ("optimizer", jf64s(&l.optimizer)),
    ])
}

fn glane(j: &Json) -> Result<LaneState> {
    Ok(LaneState {
        status: status_from(&gstr(field(j, "status")?, "lane.status")?)?,
        cc: gusize(field(j, "cc")?, "lane.cc")? as u32,
        p: gusize(field(j, "p")?, "lane.p")? as u32,
        has_pending_decision: gbool(field(j, "has_pending_decision")?, "lane.pending")?,
        delivered_bytes: gf64(field(j, "delivered_bytes")?, "lane.delivered_bytes")?,
        window: gwindow(field(j, "window")?)?,
        reward: gtracker(field(j, "reward")?)?,
        optimizer: gf64s(field(j, "optimizer")?, "lane.optimizer")?,
    })
}

fn window_json(w: &WindowState) -> Json {
    Json::obj(vec![
        ("rtt_min_s", jf64(w.rtt_min_s)),
        ("prev_rtt_s", jopt(w.prev_rtt_s, jf64)),
        ("buf", Json::Arr(w.buf.iter().map(|&x| jf32(x)).collect())),
    ])
}

fn gwindow(j: &Json) -> Result<WindowState> {
    Ok(WindowState {
        rtt_min_s: gf64(field(j, "rtt_min_s")?, "window.rtt_min_s")?,
        prev_rtt_s: gopt(field(j, "prev_rtt_s")?, |x| gf64(x, "window.prev_rtt_s"))?,
        buf: garr(field(j, "buf")?, "window.buf")?
            .iter()
            .map(|x| gf32(x, "window.buf"))
            .collect::<Result<Vec<_>>>()?,
    })
}

fn tracker_json(t: &TrackerState) -> Json {
    Json::obj(vec![
        ("hist_util", jf64s(&t.hist_util)),
        ("hist_thr", jf64s(&t.hist_thr)),
        ("hist_energy", jf64s(&t.hist_energy)),
        ("prev_metric", jopt(t.prev_metric, jf64)),
    ])
}

fn gtracker(j: &Json) -> Result<TrackerState> {
    Ok(TrackerState {
        hist_util: gf64s(field(j, "hist_util")?, "reward.hist_util")?,
        hist_thr: gf64s(field(j, "hist_thr")?, "reward.hist_thr")?,
        hist_energy: gf64s(field(j, "hist_energy")?, "reward.hist_energy")?,
        prev_metric: gopt(field(j, "prev_metric")?, |x| gf64(x, "reward.prev_metric"))?,
    })
}

fn rails_json(r: &RailEnergy) -> Json {
    Json::obj(vec![
        ("cpu_j", jf64(r.cpu_j)),
        ("nic_j", jf64(r.nic_j)),
        ("fixed_j", jf64(r.fixed_j)),
        ("idle_j", jf64(r.idle_j)),
    ])
}

fn grails(j: &Json) -> Result<RailEnergy> {
    Ok(RailEnergy {
        cpu_j: gf64(field(j, "cpu_j")?, "rails.cpu_j")?,
        nic_j: gf64(field(j, "nic_j")?, "rails.nic_j")?,
        fixed_j: gf64(field(j, "fixed_j")?, "rails.fixed_j")?,
        idle_j: gf64(field(j, "idle_j")?, "rails.idle_j")?,
    })
}

fn account_json(a: &AccountState) -> Json {
    Json::obj(vec![
        ("rng", jrng(&a.rng)),
        ("total_j", jf64(a.total_j)),
        ("rails", rails_json(&a.rails)),
    ])
}

fn gaccount(j: &Json) -> Result<AccountState> {
    Ok(AccountState {
        rng: grng(field(j, "rng")?, "account.rng")?,
        total_j: gf64(field(j, "total_j")?, "account.total_j")?,
        rails: grails(field(j, "rails")?)?,
    })
}

fn ledger_json(l: &LedgerState) -> Json {
    Json::obj(vec![
        ("rng", jrng(&l.rng)),
        ("total_j", jf64(l.total_j)),
        ("rails", rails_json(&l.rails)),
        ("accounts", Json::Arr(l.accounts.iter().map(account_json).collect())),
    ])
}

fn gledger(j: &Json) -> Result<LedgerState> {
    Ok(LedgerState {
        rng: grng(field(j, "rng")?, "ledger.rng")?,
        total_j: gf64(field(j, "total_j")?, "ledger.total_j")?,
        rails: grails(field(j, "rails")?)?,
        accounts: garr(field(j, "accounts")?, "ledger.accounts")?
            .iter()
            .map(gaccount)
            .collect::<Result<Vec<_>>>()?,
    })
}

fn sim_json(s: &SimState) -> Json {
    Json::obj(vec![
        ("time_s", jf64(s.time_s)),
        ("rng", jrng(&s.rng)),
        ("active_total", Json::from(s.active_total)),
        ("flows", Json::Arr(s.flows.iter().map(flow_json).collect())),
        ("segments", Json::Arr(s.segments.iter().map(segment_json).collect())),
        ("arena", arena_json(&s.arena)),
    ])
}

fn gsim(j: &Json) -> Result<SimState> {
    Ok(SimState {
        time_s: gf64(field(j, "time_s")?, "sim.time_s")?,
        rng: grng(field(j, "rng")?, "sim.rng")?,
        active_total: gusize(field(j, "active_total")?, "sim.active_total")?,
        flows: garr(field(j, "flows")?, "sim.flows")?
            .iter()
            .map(gflow)
            .collect::<Result<Vec<_>>>()?,
        segments: garr(field(j, "segments")?, "sim.segments")?
            .iter()
            .map(gsegment)
            .collect::<Result<Vec<_>>>()?,
        arena: garena(field(j, "arena")?)?,
    })
}

fn task_json(t: &(usize, usize, usize)) -> Json {
    Json::Arr(vec![Json::from(t.0), Json::from(t.1), Json::from(t.2)])
}

fn gtask(j: &Json) -> Result<(usize, usize, usize)> {
    let trip = garr(j, "flow.tasks")?;
    if trip.len() != 3 {
        return Err(anyhow!("snapshot: flow.tasks entries must be [base, created, cap]"));
    }
    let base = gusize(&trip[0], "flow.tasks.base")?;
    let created = gusize(&trip[1], "flow.tasks.created")?;
    let cap = gusize(&trip[2], "flow.tasks.cap")?;
    Ok((base, created, cap))
}

fn flow_json(f: &FlowState) -> Json {
    Json::obj(vec![
        ("tasks", Json::Arr(f.tasks.iter().map(task_json).collect())),
        ("cc_active", Json::from(f.cc_active)),
        ("p_active", Json::from(f.p_active)),
        ("active_streams", Json::from(f.active_streams)),
        ("task_io_gbps", jf64(f.task_io_gbps)),
        ("stream_cap_gbps", jf64(f.stream_cap_gbps)),
        ("demand_cap_gbps", jf64(f.demand_cap_gbps)),
    ])
}

fn gflow(j: &Json) -> Result<FlowState> {
    let tasks = garr(field(j, "tasks")?, "flow.tasks")?
        .iter()
        .map(gtask)
        .collect::<Result<Vec<_>>>()?;
    Ok(FlowState {
        tasks,
        cc_active: gusize(field(j, "cc_active")?, "flow.cc_active")?,
        p_active: gusize(field(j, "p_active")?, "flow.p_active")?,
        active_streams: gusize(field(j, "active_streams")?, "flow.active_streams")?,
        task_io_gbps: gf64(field(j, "task_io_gbps")?, "flow.task_io_gbps")?,
        stream_cap_gbps: gf64(field(j, "stream_cap_gbps")?, "flow.stream_cap_gbps")?,
        demand_cap_gbps: gf64(field(j, "demand_cap_gbps")?, "flow.demand_cap_gbps")?,
    })
}

fn segment_json(s: &SegmentState) -> Json {
    let background = match s.background {
        Some((high, scale)) => Json::Arr(vec![Json::from(high), jf64(scale)]),
        None => Json::Null,
    };
    Json::obj(vec![("queue_bits", jf64(s.queue_bits)), ("background", background)])
}

fn gbackground(j: &Json) -> Result<(bool, f64)> {
    let pair = garr(j, "segment.background")?;
    if pair.len() != 2 {
        return Err(anyhow!("snapshot: segment.background must be [high, scale]"));
    }
    let high = gbool(&pair[0], "segment.background")?;
    let scale = gf64(&pair[1], "segment.background")?;
    Ok((high, scale))
}

fn gsegment(j: &Json) -> Result<SegmentState> {
    Ok(SegmentState {
        queue_bits: gf64(field(j, "queue_bits")?, "segment.queue_bits")?,
        background: gopt(field(j, "background")?, gbackground)?,
    })
}

fn arena_json(a: &ArenaState) -> Json {
    Json::obj(vec![
        ("cwnd", jf64s(&a.cwnd)),
        ("w_max", jf64s(&a.w_max)),
        ("ssthresh", jf64s(&a.ssthresh)),
        ("epoch_t", jf64s(&a.epoch_t)),
        ("since_cut", jf64s(&a.since_cut)),
        ("in_slow_start", jbools(&a.in_slow_start)),
        ("active", jbools(&a.active)),
    ])
}

fn garena(j: &Json) -> Result<ArenaState> {
    Ok(ArenaState {
        cwnd: gf64s(field(j, "cwnd")?, "arena.cwnd")?,
        w_max: gf64s(field(j, "w_max")?, "arena.w_max")?,
        ssthresh: gf64s(field(j, "ssthresh")?, "arena.ssthresh")?,
        epoch_t: gf64s(field(j, "epoch_t")?, "arena.epoch_t")?,
        since_cut: gf64s(field(j, "since_cut")?, "arena.since_cut")?,
        in_slow_start: gbools(field(j, "in_slow_start")?, "arena.in_slow_start")?,
        active: gbools(field(j, "active")?, "arena.active")?,
    })
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn float_codec_round_trips_awkward_bit_patterns() {
        for x in [0.0f64, -0.0, 0.1, 0.1 + 0.2, 1e-308, f64::MAX, f64::MIN_POSITIVE, -17.25] {
            let back = gf64(&jf64(x), "t").unwrap();
            assert_eq!(back.to_bits(), x.to_bits(), "f64 {x:?} lost bits");
        }
        for x in [0.0f32, -0.0, 0.1, 3.4e38, f32::MIN_POSITIVE] {
            let back = gf32(&jf32(x), "t").unwrap();
            assert_eq!(back.to_bits(), x.to_bits(), "f32 {x:?} lost bits");
        }
        assert_eq!(gu64(&ju64(u64::MAX), "t").unwrap(), u64::MAX);
    }

    fn sample_snapshot() -> ServeSnapshot {
        let session = SessionState {
            mi: 7,
            lanes: vec![LaneState {
                status: LaneStatus::Paused,
                cc: 4,
                p: 2,
                has_pending_decision: true,
                delivered_bytes: 0.1 + 0.2,
                window: WindowState {
                    rtt_min_s: 0.023,
                    prev_rtt_s: Some(0.5),
                    buf: vec![0.1f32, -3.25],
                },
                reward: TrackerState {
                    hist_util: vec![0.3, 0.7],
                    hist_thr: vec![],
                    hist_energy: vec![1e9],
                    prev_metric: None,
                },
                optimizer: vec![1.0, f64::MIN_POSITIVE],
            }],
            energy: vec![LedgerState {
                rng: [1, 2, 3, u64::MAX],
                total_j: 123.456,
                rails: RailEnergy { cpu_j: 0.1, nic_j: -0.0, fixed_j: 3.0, idle_j: 4.0 },
                accounts: vec![AccountState {
                    rng: [9, 8, 7, 6],
                    total_j: 0.25,
                    rails: RailEnergy::default(),
                }],
            }],
            sim: SimState {
                time_s: 17.25,
                rng: [5, 6, 7, 8],
                active_total: 2,
                flows: vec![FlowState {
                    tasks: vec![(0, 1, 2), (2, 2, 2)],
                    cc_active: 1,
                    p_active: 2,
                    active_streams: 2,
                    task_io_gbps: 10.0,
                    stream_cap_gbps: 0.75,
                    demand_cap_gbps: 1e18,
                }],
                segments: vec![
                    SegmentState { queue_bits: 1234.5, background: Some((true, 0.5)) },
                    SegmentState { queue_bits: 0.0, background: None },
                ],
                arena: ArenaState {
                    cwnd: vec![1.5, 0.1],
                    w_max: vec![2.5, 0.2],
                    ssthresh: vec![3.5, 0.3],
                    epoch_t: vec![0.0, 0.4],
                    since_cut: vec![1.0, 0.5],
                    in_slow_start: vec![true, false],
                    active: vec![false, true],
                },
            },
        };
        ServeSnapshot {
            spec: ServeSpec {
                scenario: "calm".to_string(),
                schedule: Some("churn-heavy".to_string()),
                methods: vec!["rclone".to_string(), "2-phase".to_string()],
                hosts: 1,
                seed: 0x9E3779B97F4A7C15,
                mi_s: 1.0,
                max_mis: 40,
                observe_paused: false,
                faults: None,
            },
            admits: vec![AdmitRec {
                method: "rclone".to_string(),
                files: 8,
                file_bytes: 128 << 20,
                name: Some("rclone#0".to_string()),
                seed: Some(12345),
                max_lifetime_mis: Some(40),
            }],
            queue: vec![
                PendingOp {
                    at_mi: 9,
                    op: OpKind::Admit(AdmitRec {
                        method: "2-phase".to_string(),
                        files: 4,
                        file_bytes: 64 << 20,
                        name: None,
                        seed: None,
                        max_lifetime_mis: None,
                    }),
                },
                PendingOp { at_mi: 12, op: OpKind::Pause(0) },
                PendingOp { at_mi: 14, op: OpKind::Resume(0) },
                PendingOp { at_mi: 40, op: OpKind::Cancel(1) },
            ],
            state: FleetState::Single(Box::new(session)),
        }
    }

    #[test]
    fn snapshot_document_round_trips_exactly() {
        let snap = sample_snapshot();
        let doc = snap.to_json();
        let back = ServeSnapshot::from_json(&doc).unwrap();
        assert_eq!(back, snap);
        // And through the textual form (what the file on disk holds).
        let reparsed = Json::parse(&doc.to_string()).unwrap();
        assert_eq!(ServeSnapshot::from_json(&reparsed).unwrap(), snap);
    }

    #[test]
    fn snapshot_file_round_trips_and_rejects_future_versions() {
        let dir = std::env::temp_dir().join("sparta_serve_snapshot_unit");
        let _ = std::fs::remove_dir_all(&dir);
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join("snap.json");
        let snap = sample_snapshot();
        snap.save(&path).unwrap();
        assert_eq!(ServeSnapshot::load(&path).unwrap(), snap);

        let mut doc = snap.to_json();
        if let Json::Obj(o) = &mut doc {
            o.insert("version".to_string(), Json::from(SNAPSHOT_VERSION + 1));
        }
        let err = ServeSnapshot::from_json(&doc).unwrap_err();
        assert!(err.to_string().contains("version"), "unexpected error: {err:#}");
    }

    #[test]
    fn cluster_state_round_trips() {
        let single = match sample_snapshot().state {
            FleetState::Single(s) => *s,
            FleetState::Cluster(_) => unreachable!(),
        };
        let mut snap = sample_snapshot();
        snap.spec.hosts = 2;
        snap.state =
            FleetState::Cluster(ClusterState { mi: 7, hosts: vec![single.clone(), single] });
        let back = ServeSnapshot::from_json(&snap.to_json()).unwrap();
        assert_eq!(back, snap);
    }
}
