//! The serve control-plane wire protocol: one JSON object per line.
//!
//! Requests are `{"cmd": "...", ...}`; every request gets exactly one
//! reply line, `{"ok": true, ...}` or `{"ok": false, "error": "..."}`.
//! A `subscribe` reply is followed by the live event stream (the same
//! JSONL records `--events` writes) until the connection closes. The
//! same parser serves the daemon and `sparta serve-ctl`, so the two
//! cannot drift.
//!
//! Commands:
//!
//! | cmd        | fields                                                  |
//! |------------|---------------------------------------------------------|
//! | `admit`    | `method` (required), `files`, `file_bytes`, `name`, `seed`, `max_lifetime_mis`, `at_mi` |
//! | `pause` / `resume` / `cancel` | `lane` (required), `at_mi`           |
//! | `status`   | —                                                       |
//! | `snapshot` | `path` (required), `at_mi`, `halt`                      |
//! | `subscribe`| —                                                       |
//! | `go`       | — (release a `--hold` daemon)                           |
//! | `shutdown` | —                                                       |
//!
//! `at_mi` schedules the op for a future MI boundary; omitted, it lands
//! at the next one. Scheduling ops at explicit boundaries is what makes
//! socket-driven runs reproducible enough to diff byte-for-byte.

use super::snapshot::AdmitRec;
use crate::util::json::Json;
use anyhow::{anyhow, Result};

/// Default per-admission workload when the request does not override it:
/// 8 files of 128 MiB.
pub const DEFAULT_FILES: usize = 8;
pub const DEFAULT_FILE_BYTES: u64 = 128 << 20;

/// A parsed control request.
#[derive(Debug, Clone, PartialEq)]
pub enum Request {
    Admit { rec: AdmitRec, at_mi: Option<usize> },
    Pause { lane: usize, at_mi: Option<usize> },
    Resume { lane: usize, at_mi: Option<usize> },
    Cancel { lane: usize, at_mi: Option<usize> },
    Status,
    Snapshot { path: String, at_mi: Option<usize>, halt: bool },
    Subscribe,
    Go,
    Shutdown,
}

/// Parse one request line. Unknown commands and malformed JSON are
/// errors; unknown *fields* are ignored (forward compatibility).
pub fn parse_request(line: &str) -> Result<Request> {
    let j = Json::parse(line).map_err(|e| anyhow!("bad request: {e}"))?;
    let cmd = get_str(&j, "cmd").ok_or_else(|| anyhow!("request needs 'cmd'"))?;
    let at_mi = get_usize(&j, "at_mi");
    match cmd.as_str() {
        "admit" => {
            let method = get_str(&j, "method").ok_or_else(|| anyhow!("admit needs 'method'"))?;
            let rec = AdmitRec {
                method,
                files: get_usize(&j, "files").unwrap_or(DEFAULT_FILES),
                file_bytes: get_u64(&j, "file_bytes").unwrap_or(DEFAULT_FILE_BYTES),
                name: get_str(&j, "name"),
                seed: get_u64(&j, "seed"),
                max_lifetime_mis: get_usize(&j, "max_lifetime_mis"),
            };
            Ok(Request::Admit { rec, at_mi })
        }
        "pause" | "resume" | "cancel" => {
            let lane = get_usize(&j, "lane").ok_or_else(|| anyhow!("{cmd} needs 'lane'"))?;
            Ok(match cmd.as_str() {
                "pause" => Request::Pause { lane, at_mi },
                "resume" => Request::Resume { lane, at_mi },
                _ => Request::Cancel { lane, at_mi },
            })
        }
        "status" => Ok(Request::Status),
        "snapshot" => {
            let path = get_str(&j, "path").ok_or_else(|| anyhow!("snapshot needs 'path'"))?;
            let halt = j.get("halt").and_then(Json::as_bool).unwrap_or(false);
            Ok(Request::Snapshot { path, at_mi, halt })
        }
        "subscribe" => Ok(Request::Subscribe),
        "go" => Ok(Request::Go),
        "shutdown" => Ok(Request::Shutdown),
        other => Err(anyhow!("unknown cmd '{other}'")),
    }
}

fn get_str(j: &Json, key: &str) -> Option<String> {
    j.get(key).and_then(Json::as_str).map(str::to_string)
}

fn get_usize(j: &Json, key: &str) -> Option<usize> {
    j.get(key).and_then(Json::as_usize)
}

/// `u64` request fields accept both a JSON number and a decimal string
/// (numbers above 2^53 only survive the string form).
fn get_u64(j: &Json, key: &str) -> Option<u64> {
    match j.get(key)? {
        Json::Str(s) => s.parse::<u64>().ok(),
        other => other.as_f64().map(|x| x as u64),
    }
}

/// An `{"ok": true, ...}` reply line (no trailing newline).
pub fn ok_line(extra: Vec<(&str, Json)>) -> String {
    let mut fields = vec![("ok", Json::from(true))];
    fields.extend(extra);
    Json::obj(fields).to_string()
}

/// An `{"ok": false, "error": ...}` reply line (no trailing newline).
pub fn err_line(msg: &str) -> String {
    Json::obj(vec![("ok", Json::from(false)), ("error", Json::from(msg))]).to_string()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn admit_parses_with_defaults_and_overrides() {
        let r = parse_request(r#"{"cmd":"admit","method":"rclone"}"#).unwrap();
        match r {
            Request::Admit { rec, at_mi } => {
                assert_eq!(rec.method, "rclone");
                assert_eq!(rec.files, DEFAULT_FILES);
                assert_eq!(rec.file_bytes, DEFAULT_FILE_BYTES);
                assert_eq!(rec.name, None);
                assert_eq!(rec.seed, None);
                assert_eq!(rec.max_lifetime_mis, None);
                assert_eq!(at_mi, None);
            }
            other => panic!("wrong parse: {other:?}"),
        }
        let line = r#"{"cmd":"admit","method":"2-phase","files":3,"file_bytes":1024,
                       "name":"x","seed":"18446744073709551615","max_lifetime_mis":9,"at_mi":4}"#;
        let line = line.replace('\n', " ");
        match parse_request(&line).unwrap() {
            Request::Admit { rec, at_mi } => {
                assert_eq!(rec.files, 3);
                assert_eq!(rec.file_bytes, 1024);
                assert_eq!(rec.name.as_deref(), Some("x"));
                assert_eq!(rec.seed, Some(u64::MAX));
                assert_eq!(rec.max_lifetime_mis, Some(9));
                assert_eq!(at_mi, Some(4));
            }
            other => panic!("wrong parse: {other:?}"),
        }
    }

    #[test]
    fn control_and_simple_commands_parse() {
        let r = parse_request(r#"{"cmd":"pause","lane":2,"at_mi":10}"#).unwrap();
        assert_eq!(r, Request::Pause { lane: 2, at_mi: Some(10) });
        let r = parse_request(r#"{"cmd":"resume","lane":2}"#).unwrap();
        assert_eq!(r, Request::Resume { lane: 2, at_mi: None });
        let r = parse_request(r#"{"cmd":"cancel","lane":0}"#).unwrap();
        assert_eq!(r, Request::Cancel { lane: 0, at_mi: None });
        let r = parse_request(r#"{"cmd":"snapshot","path":"s.json","at_mi":20,"halt":true}"#);
        let want = Request::Snapshot { path: "s.json".to_string(), at_mi: Some(20), halt: true };
        assert_eq!(r.unwrap(), want);
        assert_eq!(parse_request(r#"{"cmd":"status"}"#).unwrap(), Request::Status);
        assert_eq!(parse_request(r#"{"cmd":"subscribe"}"#).unwrap(), Request::Subscribe);
        assert_eq!(parse_request(r#"{"cmd":"go"}"#).unwrap(), Request::Go);
        assert_eq!(parse_request(r#"{"cmd":"shutdown"}"#).unwrap(), Request::Shutdown);
    }

    #[test]
    fn malformed_requests_are_rejected() {
        assert!(parse_request("not json").is_err());
        assert!(parse_request(r#"{"no_cmd":1}"#).is_err());
        assert!(parse_request(r#"{"cmd":"warp"}"#).is_err());
        assert!(parse_request(r#"{"cmd":"admit"}"#).is_err(), "admit without method");
        assert!(parse_request(r#"{"cmd":"pause"}"#).is_err(), "pause without lane");
        assert!(parse_request(r#"{"cmd":"snapshot"}"#).is_err(), "snapshot without path");
    }

    #[test]
    fn reply_lines_are_single_json_objects() {
        let ok = ok_line(vec![("queued_at_mi", Json::from(7usize))]);
        let j = Json::parse(&ok).unwrap();
        assert_eq!(j.get("ok").and_then(Json::as_bool), Some(true));
        assert_eq!(j.get("queued_at_mi").and_then(Json::as_usize), Some(7));
        let err = err_line("nope");
        let j = Json::parse(&err).unwrap();
        assert_eq!(j.get("ok").and_then(Json::as_bool), Some(false));
        assert_eq!(j.get("error").and_then(Json::as_str), Some("nope"));
    }
}
