//! The serve daemon: a Unix-socket control plane wrapped around a
//! [`ServeEngine`], plus the pacer loop that steps it.
//!
//! Threading model: the pacer (the caller's thread) is the only thread
//! that touches the engine. A listener thread accepts control
//! connections and spawns one handler thread per connection; handlers
//! parse request lines and forward them to the pacer over an mpsc
//! channel, blocking on a per-request reply channel. The pacer drains
//! control messages at every MI boundary — so every op lands at a
//! boundary, which is what keeps socket-driven runs replayable — and
//! replies immediately (scheduled ops acknowledge with the boundary
//! they will fire at).
//!
//! Event fan-out: each MI's events go to the `--events` JSONL sink and
//! to every subscribed connection (a `subscribe` request hands its
//! socket's write half to the pacer). Dead subscribers are dropped on
//! the first failed write.
//!
//! Pacing: `time_scale` 0 steps as fast as possible; `s > 0` sleeps
//! `mi_s / s` wall seconds per MI (1 = real time). `--hold` boots the
//! daemon paused at MI 0 until a `go` request releases it, so a test
//! harness can queue admissions before the first step.

use super::engine::ServeEngine;
use super::protocol::{err_line, ok_line, parse_request, Request};
use super::snapshot::{OpKind, ServeSnapshot};
use super::ServeSpec;
use crate::coordinator::Event;
use crate::experiments::SpartaCtx;
use crate::telemetry::{event_json, JsonlSink, TelemetrySink};
use crate::util::json::Json;
use anyhow::{anyhow, Context, Result};
use std::fs::File;
use std::io::{BufRead, BufReader, BufWriter, Write};
use std::os::unix::net::{UnixListener, UnixStream};
use std::path::{Path, PathBuf};
use std::sync::mpsc::{channel, Receiver, Sender};
use std::thread;
use std::time::Duration;

/// How to boot: a fresh [`ServeSpec`], or resume from a snapshot file
/// (which carries its own spec).
pub enum Boot {
    Fresh(ServeSpec),
    Restore(PathBuf),
}

/// Daemon knobs that are *not* part of the logical run — none of these
/// affect the event stream, so they may differ between an interrupted
/// run and its restore without breaking bit-identity.
pub struct ServeOptions {
    /// Control socket path (rebound on boot, removed on exit).
    pub socket: PathBuf,
    /// Optional JSONL event log.
    pub events: Option<PathBuf>,
    /// Simulated-to-wall-clock ratio: 0 = as fast as possible,
    /// 1 = real time, 10 = ten simulated seconds per wall second.
    pub time_scale: f64,
    /// Boot paused; the first `go` request releases the pacer.
    pub hold: bool,
    /// Intra-step cluster worker threads for multi-host fleets (§Perf in
    /// [`crate::coordinator::cluster`]; 0/1 = serial). Wall-clock only —
    /// the stream and snapshots are byte-identical at any value, so a
    /// restore may pick a different count than the interrupted run.
    pub step_threads: usize,
}

/// One parsed request in flight from a handler thread to the pacer.
struct CtlMsg {
    req: Request,
    /// Per-request reply line, sent exactly once.
    reply: Sender<String>,
    /// The connection's write half, riding along on `subscribe`.
    stream: Option<UnixStream>,
}

/// Run the daemon to completion: until `max_mis`, a `shutdown` request,
/// or a halting snapshot. The socket is (re)bound on entry and removed
/// on exit, success or failure.
pub fn run_daemon(ctx: SpartaCtx, boot: Boot, opts: ServeOptions) -> Result<()> {
    let mut engine = match boot {
        Boot::Fresh(spec) => ServeEngine::new(ctx, spec, opts.step_threads)?,
        Boot::Restore(path) => {
            let snap = ServeSnapshot::load(&path)
                .with_context(|| format!("loading snapshot {}", path.display()))?;
            ServeEngine::restore(ctx, snap, opts.step_threads)?
        }
    };
    let _ = std::fs::remove_file(&opts.socket);
    let listener = UnixListener::bind(&opts.socket)
        .with_context(|| format!("binding {}", opts.socket.display()))?;
    let (tx, rx) = channel();
    // The listener thread owns the only long-lived sender; it blocks in
    // accept() and dies with the process when the pacer returns.
    thread::spawn(move || listen_loop(listener, tx));
    let result = pacer_loop(&mut engine, &rx, &opts);
    let _ = std::fs::remove_file(&opts.socket);
    result
}

fn listen_loop(listener: UnixListener, tx: Sender<CtlMsg>) {
    for conn in listener.incoming() {
        let Ok(stream) = conn else { break };
        let tx = tx.clone();
        thread::spawn(move || handle_conn(stream, tx));
    }
}

/// One control connection: request lines in, one reply line out per
/// request. Parse errors are answered locally; everything else round
/// trips through the pacer.
fn handle_conn(stream: UnixStream, tx: Sender<CtlMsg>) {
    let Ok(read_half) = stream.try_clone() else { return };
    let mut writer = stream;
    let reader = BufReader::new(read_half);
    for line in reader.lines() {
        let Ok(line) = line else { break };
        if line.trim().is_empty() {
            continue;
        }
        let reply_line = match parse_request(&line) {
            Err(e) => err_line(&format!("{e:#}")),
            Ok(req) => {
                let sub = if req == Request::Subscribe { writer.try_clone().ok() } else { None };
                let (reply_tx, reply_rx) = channel();
                let msg = CtlMsg { req, reply: reply_tx, stream: sub };
                if tx.send(msg).is_err() {
                    break; // pacer gone: the daemon is shutting down
                }
                match reply_rx.recv() {
                    Ok(r) => r,
                    Err(_) => break,
                }
            }
        };
        if writeln!(writer, "{reply_line}").is_err() {
            break;
        }
    }
}

/// The pacer: one iteration per MI boundary. Drain control (blocking
/// cheaply while held), write due snapshots, step, fan the MI's events
/// out, sleep if pacing slower than flat out.
fn pacer_loop(engine: &mut ServeEngine, rx: &Receiver<CtlMsg>, opts: &ServeOptions) -> Result<()> {
    let mut sink = match &opts.events {
        Some(path) => {
            let f = File::create(path).with_context(|| format!("creating {}", path.display()))?;
            Some(JsonlSink::new(BufWriter::new(f)))
        }
        None => None,
    };
    let mut subscribers: Vec<UnixStream> = Vec::new();
    let mut snaps: Vec<(PathBuf, usize, bool)> = Vec::new();
    let mut holding = opts.hold;
    let mut shutdown = false;
    let mut events: Vec<Event> = Vec::new();
    loop {
        loop {
            let msg = if holding {
                rx.recv_timeout(Duration::from_millis(50)).ok()
            } else {
                rx.try_recv().ok()
            };
            let Some(msg) = msg else { break };
            ctl(engine, msg, &mut subscribers, &mut snaps, &mut holding, &mut shutdown);
        }
        if shutdown {
            break;
        }
        // Write snapshots due at this boundary; a halting snapshot ends
        // the run (its restore continues the stream bit-identically).
        let mi = engine.mi();
        let mut halt = false;
        let mut failed = None;
        snaps.retain(|(path, at, h)| {
            if *at > mi {
                return true;
            }
            match engine.snapshot().and_then(|s| s.save(path)) {
                Ok(()) => halt |= *h,
                Err(e) => failed = Some(e),
            }
            false
        });
        if let Some(e) = failed {
            return Err(e);
        }
        if halt {
            break;
        }
        if holding {
            continue;
        }
        if mi >= engine.spec().max_mis {
            break;
        }
        engine.step(&mut events)?;
        for ev in &events {
            if let Some(s) = sink.as_mut() {
                s.on_event(ev);
            }
            if !subscribers.is_empty() {
                let line = format!("{}\n", event_json(ev));
                subscribers.retain_mut(|s| s.write_all(line.as_bytes()).is_ok());
            }
        }
        if opts.time_scale > 0.0 {
            thread::sleep(Duration::from_secs_f64(engine.spec().mi_s / opts.time_scale));
        }
    }
    Ok(()) // sink drops here, flushing the event log
}

/// Apply one control message at an MI boundary and answer it.
fn ctl(
    engine: &mut ServeEngine,
    msg: CtlMsg,
    subscribers: &mut Vec<UnixStream>,
    snaps: &mut Vec<(PathBuf, usize, bool)>,
    holding: &mut bool,
    shutdown: &mut bool,
) {
    let CtlMsg { req, reply, stream } = msg;
    let line = match req {
        Request::Admit { rec, at_mi } => queued(engine.enqueue(OpKind::Admit(rec), at_mi)),
        Request::Pause { lane, at_mi } => queued(engine.enqueue(OpKind::Pause(lane), at_mi)),
        Request::Resume { lane, at_mi } => queued(engine.enqueue(OpKind::Resume(lane), at_mi)),
        Request::Cancel { lane, at_mi } => queued(engine.enqueue(OpKind::Cancel(lane), at_mi)),
        Request::Status => ok_line(vec![("status", engine.status_json())]),
        Request::Snapshot { path, at_mi, halt } => {
            let at = at_mi.unwrap_or_else(|| engine.mi());
            snaps.push((PathBuf::from(path), at, halt));
            ok_line(vec![("snapshot_at_mi", Json::from(at)), ("halt", Json::from(halt))])
        }
        Request::Subscribe => match stream {
            Some(s) => {
                subscribers.push(s);
                ok_line(vec![("subscribed", Json::from(true))])
            }
            None => err_line("subscribe stream unavailable"),
        },
        Request::Go => {
            *holding = false;
            ok_line(vec![("running", Json::from(true))])
        }
        Request::Shutdown => {
            *shutdown = true;
            ok_line(vec![("stopping", Json::from(true))])
        }
    };
    let _ = reply.send(line);
}

fn queued(at: Result<usize>) -> String {
    match at {
        Ok(at) => ok_line(vec![("queued_at_mi", Json::from(at))]),
        Err(e) => err_line(&format!("{e:#}")),
    }
}

/// `sparta serve-ctl`: connect, send each request line, print each
/// reply line. If any request was a `subscribe`, the remaining event
/// stream is copied to stdout until the daemon closes the connection.
pub fn run_ctl(socket: &Path, lines: &[String]) -> Result<()> {
    let stream = connect_retry(socket)?;
    let mut writer = stream.try_clone().context("cloning control stream")?;
    let mut reader = BufReader::new(stream);
    let mut subscribed = false;
    for line in lines {
        writeln!(writer, "{line}").context("writing request")?;
        let mut reply = String::new();
        if reader.read_line(&mut reply).context("reading reply")? == 0 {
            return Err(anyhow!("daemon closed the connection"));
        }
        print!("{reply}");
        subscribed |= matches!(parse_request(line), Ok(Request::Subscribe));
    }
    if subscribed {
        for line in reader.lines() {
            let Ok(line) = line else { break };
            println!("{line}");
        }
    }
    Ok(())
}

/// The daemon binds its socket after forking away from the caller, so
/// give it ~5 s to appear before giving up.
fn connect_retry(socket: &Path) -> Result<UnixStream> {
    for _ in 0..50 {
        if let Ok(s) = UnixStream::connect(socket) {
            return Ok(s);
        }
        thread::sleep(Duration::from_millis(100));
    }
    UnixStream::connect(socket).with_context(|| format!("connecting to {}", socket.display()))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::Paths;

    #[test]
    fn daemon_answers_control_requests_and_runs_to_completion() {
        let root = std::env::temp_dir().join("sparta_serve_daemon_unit");
        let _ = std::fs::remove_dir_all(&root);
        let ctx = SpartaCtx::load(Paths::with_root(&root)).expect("fresh context loads");
        let spec = ServeSpec {
            scenario: "calm".to_string(),
            schedule: None,
            methods: vec!["rclone".to_string()],
            hosts: 1,
            seed: 5,
            mi_s: 1.0,
            max_mis: 6,
            observe_paused: false,
        };
        let socket = root.join("ctl.sock");
        let opts = ServeOptions {
            socket: socket.clone(),
            events: Some(root.join("events.jsonl")),
            time_scale: 0.0,
            hold: true,
            step_threads: 1,
        };
        let daemon = thread::spawn(move || run_daemon(ctx, Boot::Fresh(spec), opts));

        let stream = connect_retry(&socket).expect("daemon socket comes up");
        let mut writer = stream.try_clone().unwrap();
        let mut reader = BufReader::new(stream);
        let mut ask = |line: &str| -> Json {
            writeln!(writer, "{line}").unwrap();
            let mut reply = String::new();
            reader.read_line(&mut reply).unwrap();
            Json::parse(&reply).expect("reply is one JSON object")
        };

        let r = ask(r#"{"cmd":"admit","method":"rclone","files":1,"at_mi":0}"#);
        assert_eq!(r.get("ok").and_then(Json::as_bool), Some(true), "admit: {r}");
        assert_eq!(r.get("queued_at_mi").and_then(Json::as_usize), Some(0));
        let r = ask(r#"{"cmd":"admit","method":"no-such-method"}"#);
        assert_eq!(r.get("ok").and_then(Json::as_bool), Some(false), "bad admit: {r}");
        let r = ask(r#"{"cmd":"status"}"#);
        let mi = r.get("status").and_then(|s| s.get("mi")).and_then(Json::as_usize);
        assert_eq!(mi, Some(0), "held daemon must sit at MI 0: {r}");
        let r = ask(r#"{"cmd":"go"}"#);
        assert_eq!(r.get("ok").and_then(Json::as_bool), Some(true), "go: {r}");

        daemon.join().unwrap().expect("daemon exits cleanly at max_mis");
        let log = std::fs::read_to_string(root.join("events.jsonl")).unwrap();
        assert!(!log.is_empty(), "event log must be written and flushed");
        let _ = std::fs::remove_dir_all(&root);
    }
}
