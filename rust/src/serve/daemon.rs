//! The serve daemon: a Unix-socket control plane wrapped around a
//! [`ServeEngine`], plus the pacer loop that steps it.
//!
//! Threading model: the pacer (the caller's thread) is the only thread
//! that touches the engine. A listener thread accepts control
//! connections and spawns one handler thread per connection; handlers
//! parse request lines and forward them to the pacer over an mpsc
//! channel, blocking on a per-request reply channel. The pacer drains
//! control messages at every MI boundary — so every op lands at a
//! boundary, which is what keeps socket-driven runs replayable — and
//! replies immediately (scheduled ops acknowledge with the boundary
//! they will fire at).
//!
//! Event fan-out: each MI's events go to the `--events` JSONL sink and
//! to every subscribed connection (a `subscribe` request hands its
//! socket's write half to the pacer). Dead subscribers are dropped on
//! the first failed write.
//!
//! Pacing: `time_scale` 0 steps as fast as possible; `s > 0` sleeps
//! `mi_s / s` wall seconds per MI (1 = real time). `--hold` boots the
//! daemon paused at MI 0 until a `go` request releases it, so a test
//! harness can queue admissions before the first step.

use super::engine::ServeEngine;
use super::protocol::{err_line, ok_line, parse_request, Request};
use super::snapshot::{OpKind, ServeSnapshot};
use super::ServeSpec;
use crate::coordinator::Event;
use crate::experiments::SpartaCtx;
use crate::telemetry::{event_json, JsonlSink, TelemetrySink};
use crate::util::json::Json;
use anyhow::{anyhow, Context, Result};
use std::fs::File;
use std::io::{BufRead, BufReader, BufWriter, Write};
use std::os::unix::net::{UnixListener, UnixStream};
use std::path::{Path, PathBuf};
use std::sync::mpsc::{channel, Receiver, Sender};
use std::thread;
use std::time::Duration;

/// How to boot: a fresh [`ServeSpec`], or resume from a snapshot file
/// (which carries its own spec).
pub enum Boot {
    Fresh(ServeSpec),
    Restore(PathBuf),
}

/// Daemon knobs that are *not* part of the logical run — none of these
/// affect the event stream, so they may differ between an interrupted
/// run and its restore without breaking bit-identity.
pub struct ServeOptions {
    /// Control socket path (rebound on boot, removed on exit).
    pub socket: PathBuf,
    /// Optional JSONL event log.
    pub events: Option<PathBuf>,
    /// Simulated-to-wall-clock ratio: 0 = as fast as possible,
    /// 1 = real time, 10 = ten simulated seconds per wall second.
    pub time_scale: f64,
    /// Boot paused; the first `go` request releases the pacer.
    pub hold: bool,
    /// Intra-step cluster worker threads for multi-host fleets (§Perf in
    /// [`crate::coordinator::cluster`]; 0/1 = serial). Wall-clock only —
    /// the stream and snapshots are byte-identical at any value, so a
    /// restore may pick a different count than the interrupted run.
    pub step_threads: usize,
}

/// One parsed request in flight from a handler thread to the pacer.
struct CtlMsg {
    req: Request,
    /// Per-request reply line, sent exactly once.
    reply: Sender<String>,
    /// The connection's write half, riding along on `subscribe`.
    stream: Option<UnixStream>,
}

/// Run the daemon to completion: until `max_mis`, a `shutdown` request,
/// or a halting snapshot. The socket is (re)bound on entry and removed
/// on exit, success or failure.
pub fn run_daemon(ctx: SpartaCtx, boot: Boot, opts: ServeOptions) -> Result<()> {
    let mut engine = match boot {
        Boot::Fresh(spec) => ServeEngine::new(ctx, spec, opts.step_threads)?,
        Boot::Restore(path) => {
            let snap = ServeSnapshot::load(&path)
                .with_context(|| format!("loading snapshot {}", path.display()))?;
            ServeEngine::restore(ctx, snap, opts.step_threads)?
        }
    };
    let _ = std::fs::remove_file(&opts.socket);
    let listener = UnixListener::bind(&opts.socket)
        .with_context(|| format!("binding {}", opts.socket.display()))?;
    let (tx, rx) = channel();
    // The listener thread owns the only long-lived sender; it blocks in
    // accept() and dies with the process when the pacer returns.
    thread::spawn(move || listen_loop(listener, tx));
    let result = pacer_loop(&mut engine, &rx, &opts);
    let _ = std::fs::remove_file(&opts.socket);
    result
}

fn listen_loop(listener: UnixListener, tx: Sender<CtlMsg>) {
    for conn in listener.incoming() {
        let Ok(stream) = conn else { break };
        let tx = tx.clone();
        thread::spawn(move || handle_conn(stream, tx));
    }
}

/// Hard cap on one control request line. Any legitimate request fits in
/// a fraction of this; past it, the handler drains the line off the wire
/// without buffering it and answers with a structured error — one hostile
/// or corrupt client line must never take down (or balloon) the daemon.
pub const MAX_LINE_BYTES: usize = 64 * 1024;

/// One bounded line read off a control connection.
enum LineRead {
    /// A complete line (without the trailing `\n`) is in the buffer.
    Line,
    /// The line exceeded [`MAX_LINE_BYTES`]; it was drained off the wire
    /// and discarded. The connection is still in sync at the next line.
    TooLong,
    /// Peer closed the connection.
    Eof,
}

/// Read one `\n`-terminated line into `buf`, holding at most
/// [`MAX_LINE_BYTES`] of it in memory — the oversized remainder is
/// consumed and dropped chunk by chunk, so a gigabyte of garbage costs a
/// gigabyte of socket traffic but only one BufReader block of memory.
fn read_capped_line(reader: &mut impl BufRead, buf: &mut Vec<u8>) -> std::io::Result<LineRead> {
    buf.clear();
    let mut overflow = false;
    loop {
        let chunk = reader.fill_buf()?;
        if chunk.is_empty() {
            // EOF: a dangling partial line still counts (mirrors
            // `BufRead::lines`), unless it was oversized garbage.
            return Ok(match (buf.is_empty() && !overflow, overflow) {
                (true, _) => LineRead::Eof,
                (false, true) => LineRead::TooLong,
                (false, false) => LineRead::Line,
            });
        }
        match chunk.iter().position(|&b| b == b'\n') {
            Some(i) => {
                if !overflow {
                    buf.extend_from_slice(&chunk[..i]);
                }
                reader.consume(i + 1);
                let too_long = overflow || buf.len() > MAX_LINE_BYTES;
                if too_long {
                    buf.clear();
                }
                return Ok(if too_long { LineRead::TooLong } else { LineRead::Line });
            }
            None => {
                let n = chunk.len();
                if !overflow {
                    buf.extend_from_slice(chunk);
                    if buf.len() > MAX_LINE_BYTES {
                        overflow = true;
                        buf.clear();
                    }
                }
                reader.consume(n);
            }
        }
    }
}

/// One control connection: request lines in, one reply line out per
/// request. Malformed input — oversized lines, invalid UTF-8, JSON that
/// does not parse — is answered locally with a structured error and the
/// connection (and daemon) keep going; only I/O failure or EOF ends the
/// handler. Valid requests round trip through the pacer.
fn handle_conn(stream: UnixStream, tx: Sender<CtlMsg>) {
    let Ok(read_half) = stream.try_clone() else { return };
    let mut writer = stream;
    let mut reader = BufReader::new(read_half);
    let mut buf: Vec<u8> = Vec::new();
    loop {
        let reply_line = match read_capped_line(&mut reader, &mut buf) {
            Err(_) | Ok(LineRead::Eof) => break,
            Ok(LineRead::TooLong) => {
                err_line(&format!("request line exceeds {MAX_LINE_BYTES} bytes"))
            }
            Ok(LineRead::Line) => match std::str::from_utf8(&buf) {
                Err(_) => err_line("request line is not valid UTF-8"),
                Ok(line) if line.trim().is_empty() => continue,
                Ok(line) => match parse_request(line) {
                    Err(e) => err_line(&format!("{e:#}")),
                    Ok(req) => {
                        let sub =
                            if req == Request::Subscribe { writer.try_clone().ok() } else { None };
                        let (reply_tx, reply_rx) = channel();
                        let msg = CtlMsg { req, reply: reply_tx, stream: sub };
                        if tx.send(msg).is_err() {
                            return; // pacer gone: the daemon is shutting down
                        }
                        match reply_rx.recv() {
                            Ok(r) => r,
                            Err(_) => return,
                        }
                    }
                },
            },
        };
        if writeln!(writer, "{reply_line}").is_err() {
            break;
        }
    }
}

/// The pacer: one iteration per MI boundary. Drain control (blocking
/// cheaply while held), write due snapshots, step, fan the MI's events
/// out, sleep if pacing slower than flat out.
fn pacer_loop(engine: &mut ServeEngine, rx: &Receiver<CtlMsg>, opts: &ServeOptions) -> Result<()> {
    let mut sink = match &opts.events {
        Some(path) => {
            let f = File::create(path).with_context(|| format!("creating {}", path.display()))?;
            Some(JsonlSink::new(BufWriter::new(f)))
        }
        None => None,
    };
    let mut subscribers: Vec<UnixStream> = Vec::new();
    let mut snaps: Vec<(PathBuf, usize, bool)> = Vec::new();
    let mut holding = opts.hold;
    let mut shutdown = false;
    let mut events: Vec<Event> = Vec::new();
    loop {
        loop {
            let msg = if holding {
                rx.recv_timeout(Duration::from_millis(50)).ok()
            } else {
                rx.try_recv().ok()
            };
            let Some(msg) = msg else { break };
            ctl(engine, msg, &mut subscribers, &mut snaps, &mut holding, &mut shutdown);
        }
        if shutdown {
            break;
        }
        // Write snapshots due at this boundary; a halting snapshot ends
        // the run (its restore continues the stream bit-identically).
        let mi = engine.mi();
        let mut halt = false;
        let mut failed = None;
        snaps.retain(|(path, at, h)| {
            if *at > mi {
                return true;
            }
            match engine.snapshot().and_then(|s| s.save(path)) {
                Ok(()) => halt |= *h,
                Err(e) => failed = Some(e),
            }
            false
        });
        if let Some(e) = failed {
            return Err(e);
        }
        if halt {
            break;
        }
        if holding {
            continue;
        }
        if mi >= engine.spec().max_mis {
            break;
        }
        engine.step(&mut events)?;
        for ev in &events {
            if let Some(s) = sink.as_mut() {
                s.on_event(ev);
            }
            if !subscribers.is_empty() {
                let line = format!("{}\n", event_json(ev));
                subscribers.retain_mut(|s| s.write_all(line.as_bytes()).is_ok());
            }
        }
        // The event log is a product of the run, not best-effort
        // telemetry: a sink that started dropping lines (disk full,
        // deleted directory) fails the run at the boundary it happened.
        if let Some(e) = sink.as_mut().and_then(|s| s.take_error()) {
            return Err(e).with_context(|| {
                format!(
                    "writing event log {}",
                    opts.events.as_deref().unwrap_or(Path::new("?")).display()
                )
            });
        }
        if opts.time_scale > 0.0 {
            thread::sleep(Duration::from_secs_f64(engine.spec().mi_s / opts.time_scale));
        }
    }
    // Flush explicitly so a failure surfaces as a run error instead of
    // vanishing in Drop.
    if let Some(mut s) = sink.take() {
        s.flush();
        if let Some(e) = s.take_error() {
            return Err(e).with_context(|| {
                format!(
                    "flushing event log {}",
                    opts.events.as_deref().unwrap_or(Path::new("?")).display()
                )
            });
        }
    }
    Ok(())
}

/// Apply one control message at an MI boundary and answer it.
fn ctl(
    engine: &mut ServeEngine,
    msg: CtlMsg,
    subscribers: &mut Vec<UnixStream>,
    snaps: &mut Vec<(PathBuf, usize, bool)>,
    holding: &mut bool,
    shutdown: &mut bool,
) {
    let CtlMsg { req, reply, stream } = msg;
    let line = match req {
        Request::Admit { rec, at_mi } => queued(engine.enqueue(OpKind::Admit(rec), at_mi)),
        Request::Pause { lane, at_mi } => queued(engine.enqueue(OpKind::Pause(lane), at_mi)),
        Request::Resume { lane, at_mi } => queued(engine.enqueue(OpKind::Resume(lane), at_mi)),
        Request::Cancel { lane, at_mi } => queued(engine.enqueue(OpKind::Cancel(lane), at_mi)),
        Request::Status => ok_line(vec![("status", engine.status_json())]),
        Request::Snapshot { path, at_mi, halt } => {
            let at = at_mi.unwrap_or_else(|| engine.mi());
            snaps.push((PathBuf::from(path), at, halt));
            ok_line(vec![("snapshot_at_mi", Json::from(at)), ("halt", Json::from(halt))])
        }
        Request::Subscribe => match stream {
            Some(s) => {
                subscribers.push(s);
                ok_line(vec![("subscribed", Json::from(true))])
            }
            None => err_line("subscribe stream unavailable"),
        },
        Request::Go => {
            *holding = false;
            ok_line(vec![("running", Json::from(true))])
        }
        Request::Shutdown => {
            *shutdown = true;
            ok_line(vec![("stopping", Json::from(true))])
        }
    };
    let _ = reply.send(line);
}

fn queued(at: Result<usize>) -> String {
    match at {
        Ok(at) => ok_line(vec![("queued_at_mi", Json::from(at))]),
        Err(e) => err_line(&format!("{e:#}")),
    }
}

/// `sparta serve-ctl`: connect, send each request line, print each
/// reply line. If any request was a `subscribe`, the remaining event
/// stream is copied to stdout until the daemon closes the connection.
pub fn run_ctl(socket: &Path, lines: &[String]) -> Result<()> {
    let stream = connect_retry(socket)?;
    let mut writer = stream.try_clone().context("cloning control stream")?;
    let mut reader = BufReader::new(stream);
    let mut subscribed = false;
    for line in lines {
        writeln!(writer, "{line}").context("writing request")?;
        let mut reply = String::new();
        if reader.read_line(&mut reply).context("reading reply")? == 0 {
            return Err(anyhow!("daemon closed the connection"));
        }
        print!("{reply}");
        subscribed |= matches!(parse_request(line), Ok(Request::Subscribe));
    }
    if subscribed {
        for line in reader.lines() {
            let Ok(line) = line else { break };
            println!("{line}");
        }
    }
    Ok(())
}

/// The daemon binds its socket after forking away from the caller, so
/// give it ~5 s to appear before giving up.
fn connect_retry(socket: &Path) -> Result<UnixStream> {
    for _ in 0..50 {
        if let Ok(s) = UnixStream::connect(socket) {
            return Ok(s);
        }
        thread::sleep(Duration::from_millis(100));
    }
    UnixStream::connect(socket).with_context(|| format!("connecting to {}", socket.display()))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::Paths;

    /// Send one request line, read one reply line, parse it.
    fn ask(writer: &mut UnixStream, reader: &mut BufReader<UnixStream>, line: &str) -> Json {
        writeln!(writer, "{line}").unwrap();
        let mut reply = String::new();
        reader.read_line(&mut reply).unwrap();
        Json::parse(&reply).expect("reply is one JSON object")
    }

    #[test]
    fn capped_line_reader_bounds_memory_and_stays_in_sync() {
        let mut big = vec![b'x'; MAX_LINE_BYTES + 10];
        big.push(b'\n');
        big.extend_from_slice(b"ok\n");
        big.extend_from_slice(b"tail-without-newline");
        let mut r = BufReader::new(std::io::Cursor::new(big));
        let mut buf = Vec::new();
        assert!(matches!(read_capped_line(&mut r, &mut buf).unwrap(), LineRead::TooLong));
        assert!(buf.is_empty(), "oversized line must not be buffered");
        assert!(matches!(read_capped_line(&mut r, &mut buf).unwrap(), LineRead::Line));
        assert_eq!(buf, b"ok", "reader out of sync after an oversized line");
        assert!(matches!(read_capped_line(&mut r, &mut buf).unwrap(), LineRead::Line));
        assert_eq!(buf, b"tail-without-newline");
        assert!(matches!(read_capped_line(&mut r, &mut buf).unwrap(), LineRead::Eof));
    }

    #[test]
    fn daemon_answers_control_requests_and_runs_to_completion() {
        let root = std::env::temp_dir().join("sparta_serve_daemon_unit");
        let _ = std::fs::remove_dir_all(&root);
        let ctx = SpartaCtx::load(Paths::with_root(&root)).expect("fresh context loads");
        let spec = ServeSpec {
            scenario: "calm".to_string(),
            schedule: None,
            methods: vec!["rclone".to_string()],
            hosts: 1,
            seed: 5,
            mi_s: 1.0,
            max_mis: 6,
            observe_paused: false,
            faults: None,
        };
        let socket = root.join("ctl.sock");
        let opts = ServeOptions {
            socket: socket.clone(),
            events: Some(root.join("events.jsonl")),
            time_scale: 0.0,
            hold: true,
            step_threads: 1,
        };
        let daemon = thread::spawn(move || run_daemon(ctx, Boot::Fresh(spec), opts));

        let stream = connect_retry(&socket).expect("daemon socket comes up");
        let mut writer = stream.try_clone().unwrap();
        let mut reader = BufReader::new(stream);

        let r = ask(&mut writer, &mut reader, r#"{"cmd":"admit","method":"rclone","files":1,"at_mi":0}"#);
        assert_eq!(r.get("ok").and_then(Json::as_bool), Some(true), "admit: {r}");
        assert_eq!(r.get("queued_at_mi").and_then(Json::as_usize), Some(0));
        let r = ask(&mut writer, &mut reader, r#"{"cmd":"admit","method":"no-such-method"}"#);
        assert_eq!(r.get("ok").and_then(Json::as_bool), Some(false), "bad admit: {r}");

        // Garbage must bounce with a structured error, not kill the
        // connection or the daemon: broken JSON, an oversized line, and a
        // line that is not UTF-8 at all.
        let r = ask(&mut writer, &mut reader, r#"{"cmd": "adm"#);
        assert_eq!(r.get("ok").and_then(Json::as_bool), Some(false), "broken JSON: {r}");
        let huge = format!("{{\"cmd\":\"status\",\"pad\":\"{}\"}}", "x".repeat(MAX_LINE_BYTES + 1));
        let r = ask(&mut writer, &mut reader, &huge);
        assert_eq!(r.get("ok").and_then(Json::as_bool), Some(false), "oversized line: {r}");
        assert!(
            r.get("error").and_then(Json::as_str).unwrap_or("").contains("exceeds"),
            "oversized reply names the cap: {r}"
        );
        writer.write_all(&[0xC3, 0x28, b'\n']).unwrap(); // invalid UTF-8 sequence
        let mut reply = String::new();
        reader.read_line(&mut reply).unwrap();
        let r = Json::parse(&reply).expect("non-UTF-8 line still gets a JSON reply");
        assert_eq!(r.get("ok").and_then(Json::as_bool), Some(false), "non-UTF-8: {r}");

        // And the connection still works after all of it.
        let r = ask(&mut writer, &mut reader, r#"{"cmd":"status"}"#);
        let mi = r.get("status").and_then(|s| s.get("mi")).and_then(Json::as_usize);
        assert_eq!(mi, Some(0), "held daemon must sit at MI 0: {r}");
        let r = ask(&mut writer, &mut reader, r#"{"cmd":"go"}"#);
        assert_eq!(r.get("ok").and_then(Json::as_bool), Some(true), "go: {r}");

        daemon.join().unwrap().expect("daemon exits cleanly at max_mis");
        let log = std::fs::read_to_string(root.join("events.jsonl")).unwrap();
        assert!(!log.is_empty(), "event log must be written and flushed");
        let _ = std::fs::remove_dir_all(&root);
    }
}
