//! `sparta serve` — a long-running transfer service over the
//! [`Stepping`](crate::coordinator::Stepping) fleet API.
//!
//! The batch drivers (`sparta transfer`, `sparta fleet`) decide the whole
//! workload up front and run to completion. `serve` inverts that: a daemon
//! owns the fleet — a single-host [`Session`] or a multi-host [`Cluster`],
//! per [`ServeSpec::hosts`] — and a local-socket control plane admits,
//! pauses, resumes and cancels lanes *while it runs*. A pacer thread steps
//! one monitoring interval at a time, in scaled or real time, streaming
//! the event feed to an `--events` JSONL file and to any subscribed
//! control connections.
//!
//! The layers, bottom up:
//!
//! - [`engine::ServeEngine`] — the daemon's single-threaded core: the
//!   fleet plus a queue of pending control ops (admissions from an
//!   [`crate::scenarios::ArrivalSchedule`] or from the socket), applied at
//!   their due MI boundary. Fully in-process testable; the integration
//!   suite drives it directly.
//! - [`snapshot`] — the versioned checkpoint codec. A
//!   [`snapshot::ServeSnapshot`] carries the rebuild spec, the resolved
//!   admission replay log, the not-yet-due op queue and the fleet's
//!   bit-exact mutable state (every `f64` is serialized as its IEEE bit
//!   pattern, so nothing is lost to decimal formatting).
//! - [`protocol`] — the line-delimited JSON request/response surface
//!   shared by the daemon and `sparta serve-ctl`.
//! - [`daemon`] (unix only) — the socket listener, per-connection
//!   handlers, and the pacer loop that ties it all together.
//!
//! The headline contract is **bit-identical checkpoint/restore**: snapshot
//! a running service at an MI boundary, kill it, `sparta serve --restore
//! FILE`, and the resumed event stream concatenated onto the
//! pre-snapshot stream is byte-for-byte the stream an uninterrupted run
//! would have produced. Restore is replay-then-inject: the spec rebuilds
//! the fleet, the admission log replays every lane (regenerating seeds,
//! flows, arena rows and ledger accounts), and the captured state is then
//! injected wholesale — see [`Session::import_state`].

pub mod engine;
pub mod protocol;
pub mod snapshot;

#[cfg(unix)]
pub mod daemon;

pub use engine::ServeEngine;
pub use snapshot::{AdmitRec, OpKind, PendingOp, ServeSnapshot, SNAPSHOT_VERSION};

use crate::coordinator::{
    Cluster, ClusterState, LaneId, Session, SessionState, Stepping, INCAST_RX_OVER_WAN,
};
use crate::net::Topology;
use crate::scenarios::Scenario;
use anyhow::{anyhow, Result};

/// Everything needed to rebuild a serve fleet from scratch — the
/// constructor half of the snapshot contract. Stored verbatim in every
/// [`ServeSnapshot`] so `--restore` needs no flags.
#[derive(Debug, Clone, PartialEq)]
pub struct ServeSpec {
    /// Registered [`Scenario`] name pinning testbed + topology.
    pub scenario: String,
    /// Optional [`crate::scenarios::ArrivalSchedule`] name expanded into
    /// queued admissions at boot (fresh boots only; a restored queue
    /// already carries the not-yet-due remainder).
    pub schedule: Option<String>,
    /// Methods cycled through by schedule-driven admissions.
    pub methods: Vec<String>,
    /// 1 = single-host [`Session`]; above 1, an incast [`Cluster`].
    pub hosts: usize,
    pub seed: u64,
    /// Monitoring-interval length, simulated seconds.
    pub mi_s: f64,
    /// The pacer stops stepping at this MI.
    pub max_mis: usize,
    /// Whether paused lanes emit zero-throughput observation records.
    pub observe_paused: bool,
    /// Optional [`crate::faults::FaultSchedule`] preset name: the service
    /// runs with a seeded fault plan installed (chaos drills). A faulted
    /// service keeps serving in degraded mode but refuses to checkpoint.
    pub faults: Option<String>,
}

/// The two fleet scales behind one serve daemon, unified where the
/// [`Stepping`] trait object cannot reach (lane names, state capture).
pub enum Fleet {
    Single(Box<Session>),
    Cluster(Cluster),
}

/// A captured [`Fleet`] (the state half of a [`ServeSnapshot`]).
#[derive(Debug, Clone, PartialEq)]
pub enum FleetState {
    Single(Box<SessionState>),
    Cluster(ClusterState),
}

impl Fleet {
    /// The mutable stepping surface.
    pub fn stepping(&mut self) -> &mut dyn Stepping {
        match self {
            Fleet::Single(s) => s.as_mut(),
            Fleet::Cluster(c) => c,
        }
    }

    /// The read-only stepping surface.
    pub fn view(&self) -> &dyn Stepping {
        match self {
            Fleet::Single(s) => s.as_ref(),
            Fleet::Cluster(c) => c,
        }
    }

    pub fn lane_name(&self, id: LaneId) -> Option<&str> {
        match self {
            Fleet::Single(s) => s.lane_name(id),
            Fleet::Cluster(c) => c.lane_name(id),
        }
    }

    /// Hosts quarantined by the fault plane (always 0 for single-host
    /// fleets — a lone host has nowhere to fail over to).
    pub fn quarantined_hosts(&self) -> usize {
        match self {
            Fleet::Single(_) => 0,
            Fleet::Cluster(c) => c.quarantined_hosts(),
        }
    }

    /// Capture the fleet's mutable state at a clean MI boundary (`None`
    /// when control events are pending or the substrate cannot
    /// checkpoint itself).
    pub fn export_state(&self) -> Option<FleetState> {
        match self {
            Fleet::Single(s) => s.export_state().map(|st| FleetState::Single(Box::new(st))),
            Fleet::Cluster(c) => c.export_state().map(FleetState::Cluster),
        }
    }

    /// Inject a capture into a fleet rebuilt with the same spec and
    /// admission sequence. False on a shape mismatch.
    pub fn import_state(&mut self, state: &FleetState) -> bool {
        match (self, state) {
            (Fleet::Single(s), FleetState::Single(st)) => s.import_state(st),
            (Fleet::Cluster(c), FleetState::Cluster(st)) => c.import_state(st),
            _ => false,
        }
    }
}

/// Build the fleet a [`ServeSpec`] describes — the same construction
/// `sparta fleet` uses, so serve inherits its determinism contract: one
/// host-resolved session, or an incast cluster of per-host sessions
/// sharing the scenario testbed's WAN and one receiver.
///
/// `step_threads` is the intra-step cluster worker count (§Perf in
/// [`crate::coordinator::cluster`]) — a pure wall-clock knob, which is why
/// it is a parameter here and **not** a [`ServeSpec`] field: it never
/// affects the event stream, is not part of the logical run, and stays out
/// of snapshots (restore at any thread count). Ignored for single-host
/// specs.
pub fn build_fleet(spec: &ServeSpec, step_threads: usize) -> Result<Fleet> {
    let sc = Scenario::by_name(&spec.scenario)
        .ok_or_else(|| anyhow!("unknown scenario '{}'", spec.scenario))?;
    let hosts = spec.hosts.max(1);
    // Resolve the fault preset (if any) before building, so a bad name
    // fails boot instead of surfacing mid-run. The plan seeds from the
    // service seed and spans the pacer horizon.
    let fault_plan = match &spec.faults {
        Some(name) => {
            let preset = crate::faults::FaultSchedule::by_name(name).ok_or_else(|| {
                anyhow!(
                    "unknown fault preset '{name}' (have: {})",
                    crate::faults::FaultSchedule::names().join(", ")
                )
            })?;
            Some(preset.resolve(spec.seed, hosts, spec.max_mis))
        }
        None => None,
    };
    if hosts == 1 {
        let mut session = sc
            .session_host_resolved()
            .mi(spec.mi_s)
            .observe_paused(spec.observe_paused)
            .seed(spec.seed)
            .build();
        if let Some(plan) = fault_plan {
            session.install_faults(plan);
        }
        return Ok(Fleet::Single(Box::new(session)));
    }
    let tb = &sc.testbed;
    let mut cluster = Cluster::build(hosts, spec.seed, |h, host_seed| {
        Session::builder(tb.clone())
            .energy(tb.energy_hosts_of(h, hosts))
            .observe_paused(spec.observe_paused)
            .seed(host_seed)
            .mi(spec.mi_s)
            .topology(Topology::incast_host(tb, hosts, INCAST_RX_OVER_WAN))
            .build()
    });
    cluster.set_step_threads(step_threads.max(1));
    if let Some(plan) = fault_plan {
        cluster.install_faults(plan);
    }
    Ok(Fleet::Cluster(cluster))
}
