//! The serve daemon's single-threaded core: a [`Fleet`] plus a queue of
//! pending control operations, stepped one MI boundary at a time.
//!
//! All control flows through the op queue — schedule-driven admissions
//! queued at boot, socket requests queued by the daemon — and every op
//! carries the MI boundary it is due at. [`ServeEngine::step`] applies
//! the due ops *in insertion order* and then steps the fleet, so a run
//! is fully determined by (spec, op sequence): the property the
//! byte-identical checkpoint/restore contract rests on. The daemon's
//! sockets and pacing live in [`super::daemon`]; everything here is
//! plain and in-process, which is how the integration tests drive it.

use super::snapshot::{status_str, AdmitRec, OpKind, PendingOp, ServeSnapshot};
use super::{build_fleet, Fleet, ServeSpec};
use crate::coordinator::{Event, LaneId, LaneSpec};
use crate::experiments::fleet::EPOCH_MIS;
use crate::experiments::runner::cell_seed;
use crate::experiments::{make_optimizer, SpartaCtx};
use crate::scenarios::ArrivalSchedule;
use crate::telemetry::{FairnessSink, TelemetrySink};
use crate::transfer::TransferJob;
use crate::util::json::Json;
use anyhow::{anyhow, Result};

/// A live serve fleet with its pending-op queue and admission log.
pub struct ServeEngine {
    ctx: SpartaCtx,
    spec: ServeSpec,
    fleet: Fleet,
    /// Admissions already executed, resolved, in admission order — the
    /// snapshot replay log.
    admits: Vec<AdmitRec>,
    /// Ops waiting for their MI boundary, in arrival order.
    queue: Vec<PendingOp>,
    /// Per-epoch JFI over the event stream since (re)start, for `status`.
    fairness: FairnessSink,
    /// Fault-plane counters since (re)start, fed from the event stream.
    faulted: usize,
    retried: usize,
    migrated: usize,
}

impl ServeEngine {
    /// Boot a fresh fleet. A `spec.schedule` is expanded here into queued
    /// admissions (methods cycled per arrival, seeds/names resolved at
    /// execution), so the schedule behaves exactly like a scripted
    /// operator issuing `admit` requests at those boundaries.
    ///
    /// `step_threads` parallelizes multi-host stepping inside each MI (see
    /// [`build_fleet`]); it never affects the event stream, so it is not
    /// part of the spec (or of snapshots).
    pub fn new(ctx: SpartaCtx, spec: ServeSpec, step_threads: usize) -> Result<ServeEngine> {
        let fleet = build_fleet(&spec, step_threads)?;
        let mut queue = Vec::new();
        if let Some(name) = &spec.schedule {
            let sched = ArrivalSchedule::by_name(name)
                .ok_or_else(|| anyhow!("unknown arrival schedule '{name}'"))?;
            if spec.methods.is_empty() {
                return Err(anyhow!("a schedule needs at least one method to cycle through"));
            }
            for (k, a) in sched.arrivals_scaled(spec.seed, spec.mi_s).iter().enumerate() {
                let method = spec.methods[k % spec.methods.len()].clone();
                queue.push(PendingOp {
                    at_mi: a.at_mi,
                    op: OpKind::Admit(AdmitRec {
                        method,
                        files: a.files,
                        file_bytes: a.file_bytes,
                        name: None,
                        seed: None,
                        max_lifetime_mis: a.max_lifetime_mis,
                    }),
                });
            }
        }
        let fairness = FairnessSink::new(EPOCH_MIS);
        Ok(ServeEngine {
            ctx,
            spec,
            fleet,
            admits: Vec::new(),
            queue,
            fairness,
            faulted: 0,
            retried: 0,
            migrated: 0,
        })
    }

    /// Resume from a snapshot: rebuild the fleet from the spec, replay the
    /// admission log (regenerating every rebuild-time constant — meter
    /// seeds, flows, arena rows, ledger accounts), then inject the
    /// captured mutable state. The snapshot queue is adopted as-is; no
    /// schedule re-expansion, no lifetime re-arming — the queue already
    /// holds exactly the not-yet-applied remainder. The thread count is
    /// the restoring process's own choice — snapshots don't record it, and
    /// the tail is byte-identical at any value.
    pub fn restore(
        ctx: SpartaCtx,
        snap: ServeSnapshot,
        step_threads: usize,
    ) -> Result<ServeEngine> {
        let ServeSnapshot { spec, admits, queue, state } = snap;
        let mut fleet = build_fleet(&spec, step_threads)?;
        for rec in &admits {
            let seed = rec.seed.ok_or_else(|| anyhow!("snapshot admit: no seed"))?;
            let name = rec.name.clone().ok_or_else(|| anyhow!("snapshot admit: no name"))?;
            let (opt, engine, reward) = make_optimizer(&ctx, &rec.method, seed)?;
            let job = TransferJob::files(rec.files, rec.file_bytes);
            let lane = LaneSpec::new(opt, job).engine(engine).reward(reward).named(name);
            fleet.stepping().admit(lane);
        }
        if !fleet.import_state(&state) {
            return Err(anyhow!("snapshot state does not match the rebuilt fleet shape"));
        }
        let fairness = FairnessSink::new(EPOCH_MIS);
        Ok(ServeEngine { ctx, spec, fleet, admits, queue, fairness, faulted: 0, retried: 0, migrated: 0 })
    }

    /// Queue a control op for `at_mi` (default: the next boundary).
    /// Admissions are validated up front — unknown methods and online
    /// learners (whose training state is not snapshot-safe) are rejected
    /// at the socket instead of crashing the pacer later.
    pub fn enqueue(&mut self, op: OpKind, at_mi: Option<usize>) -> Result<usize> {
        if let OpKind::Admit(rec) = &op {
            let (probe, _, _) = make_optimizer(&self.ctx, &rec.method, 0)
                .map_err(|e| anyhow!("admit rejected: {e:#}"))?;
            if probe.is_learning() {
                return Err(anyhow!("admit rejected: learning optimizers are not snapshot-safe"));
            }
        }
        let at = at_mi.unwrap_or_else(|| self.mi());
        self.queue.push(PendingOp { at_mi: at, op });
        Ok(at)
    }

    /// Advance one monitoring interval: apply every op due at the current
    /// boundary (insertion order), step the fleet into `events`, feed the
    /// fairness series. The buffer is reclaimed by the fleet each call —
    /// after return it holds exactly this MI's events.
    pub fn step(&mut self, events: &mut Vec<Event>) -> Result<()> {
        let mi = self.fleet.view().mi();
        let mut i = 0;
        while i < self.queue.len() {
            if self.queue[i].at_mi <= mi {
                let due = self.queue.remove(i);
                self.apply(due.op)?;
            } else {
                i += 1;
            }
        }
        self.fleet.stepping().step_into(events);
        for ev in events.iter() {
            self.fairness.on_event(ev);
            match ev {
                Event::Faulted { .. } => self.faulted += 1,
                Event::Retrying { .. } => self.retried += 1,
                Event::Migrated { .. } => self.migrated += 1,
                _ => {}
            }
        }
        Ok(())
    }

    fn apply(&mut self, op: OpKind) -> Result<()> {
        match op {
            OpKind::Admit(rec) => self.apply_admit(rec),
            OpKind::Pause(l) => {
                self.fleet.stepping().pause(LaneId(l));
                Ok(())
            }
            OpKind::Resume(l) => {
                self.fleet.stepping().resume(LaneId(l));
                Ok(())
            }
            OpKind::Cancel(l) => {
                self.fleet.stepping().cancel(LaneId(l));
                Ok(())
            }
        }
    }

    /// Execute an admission: resolve seed and name from the admission
    /// index (deterministic, so a restored run resolves identically), arm
    /// the lifetime cancel, and append the resolved record to the replay
    /// log.
    fn apply_admit(&mut self, rec: AdmitRec) -> Result<()> {
        let k = self.admits.len() as u64;
        let derived = cell_seed(self.spec.seed, &rec.method, k);
        let seed = rec.seed.unwrap_or(derived);
        let name = rec.name.clone().unwrap_or_else(|| format!("{}#{k}", rec.method));
        let (opt, engine, reward) = make_optimizer(&self.ctx, &rec.method, seed)?;
        let job = TransferJob::files(rec.files, rec.file_bytes);
        let lane = LaneSpec::new(opt, job).engine(engine).reward(reward).named(name.clone());
        let id = self.fleet.stepping().admit(lane);
        if let Some(life) = rec.max_lifetime_mis {
            let at_mi = self.fleet.view().mi() + life;
            self.queue.push(PendingOp { at_mi, op: OpKind::Cancel(id.0) });
        }
        self.admits.push(AdmitRec {
            method: rec.method,
            files: rec.files,
            file_bytes: rec.file_bytes,
            name: Some(name),
            seed: Some(seed),
            max_lifetime_mis: rec.max_lifetime_mis,
        });
        Ok(())
    }

    /// Capture the complete logical state (see [`ServeSnapshot`]). Legal
    /// at any clean MI boundary — the queue is captured as-is, *including*
    /// ops due at the current MI, which the restored run applies itself.
    pub fn snapshot(&self) -> Result<ServeSnapshot> {
        let Some(state) = self.fleet.export_state() else {
            return Err(anyhow!("fleet is not at a clean MI boundary"));
        };
        Ok(ServeSnapshot {
            spec: self.spec.clone(),
            admits: self.admits.clone(),
            queue: self.queue.clone(),
            state,
        })
    }

    /// The `status` reply body: counters, per-lane table, energy truth,
    /// per-epoch JFI since (re)start.
    pub fn status_json(&self) -> Json {
        let v = self.fleet.view();
        let mut lanes = Vec::new();
        for k in 0..v.lane_count() {
            let id = LaneId(k);
            let name = self.fleet.lane_name(id).map(Json::from).unwrap_or(Json::Null);
            let status = match v.status(id) {
                Some(s) => Json::from(status_str(s)),
                None => Json::Null,
            };
            let energy = v.lane_energy_j(id).map(Json::from).unwrap_or(Json::Null);
            lanes.push(Json::obj(vec![
                ("lane", Json::from(k)),
                ("name", name),
                ("status", status),
                ("energy_j", energy),
            ]));
        }
        let mut fields = vec![
            ("mi", Json::from(v.mi())),
            ("time_s", Json::from(v.time_s())),
            ("idle", Json::from(v.is_idle())),
            ("queued_ops", Json::from(self.queue.len())),
            ("admitted", Json::from(self.admits.len())),
            ("host_energy_j", Json::from(v.host_energy_j())),
            ("epoch_jfi", Json::arr_f64(&self.fairness.epoch_jfi())),
            ("lanes", Json::Arr(lanes)),
        ];
        if let Some(r) = v.energy_rails() {
            let rails = Json::obj(vec![
                ("cpu_j", Json::from(r.cpu_j)),
                ("nic_j", Json::from(r.nic_j)),
                ("fixed_j", Json::from(r.fixed_j)),
                ("idle_j", Json::from(r.idle_j)),
            ]);
            fields.push(("rails", rails));
        }
        // Fault-plane block: present whenever the service runs with a
        // fault plan (even before anything fires), or after any fault
        // activity — absent otherwise so fault-free status replies stay
        // byte-identical to pre-fault-plane builds.
        if self.spec.faults.is_some() || self.faulted + self.retried + self.migrated > 0 {
            let preset = self.spec.faults.as_deref().map(Json::from).unwrap_or(Json::Null);
            fields.push((
                "faults",
                Json::obj(vec![
                    ("preset", preset),
                    ("faulted", Json::from(self.faulted)),
                    ("retried", Json::from(self.retried)),
                    ("migrated", Json::from(self.migrated)),
                    ("quarantined_hosts", Json::from(self.fleet.quarantined_hosts())),
                ]),
            ));
        }
        Json::obj(fields)
    }

    pub fn spec(&self) -> &ServeSpec {
        &self.spec
    }

    pub fn mi(&self) -> usize {
        self.fleet.view().mi()
    }

    pub fn time_s(&self) -> f64 {
        self.fleet.view().time_s()
    }

    pub fn is_idle(&self) -> bool {
        self.fleet.view().is_idle()
    }

    /// Ops still waiting for their boundary.
    pub fn queue_len(&self) -> usize {
        self.queue.len()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::Paths;
    use crate::telemetry::event_json;

    fn test_ctx(tag: &str) -> SpartaCtx {
        let root = std::env::temp_dir().join(format!("sparta_serve_engine_{tag}"));
        let _ = std::fs::remove_dir_all(&root);
        SpartaCtx::load(Paths::with_root(&root)).expect("fresh context loads")
    }

    fn spec(scenario: &str) -> ServeSpec {
        ServeSpec {
            scenario: scenario.to_string(),
            schedule: None,
            methods: vec!["rclone".to_string()],
            hosts: 1,
            seed: 11,
            mi_s: 1.0,
            max_mis: 24,
            observe_paused: false,
            faults: None,
        }
    }

    fn admit(method: &str, files: usize, life: Option<usize>) -> OpKind {
        OpKind::Admit(AdmitRec {
            method: method.to_string(),
            files,
            file_bytes: 32 << 20,
            name: None,
            seed: None,
            max_lifetime_mis: life,
        })
    }

    fn run_lines(engine: &mut ServeEngine, mis: usize) -> Vec<String> {
        let mut events = Vec::new();
        let mut lines = Vec::new();
        for _ in 0..mis {
            engine.step(&mut events).unwrap();
            for ev in &events {
                lines.push(event_json(ev).to_string());
            }
        }
        lines
    }

    #[test]
    fn snapshot_restore_resumes_bit_identically() {
        let mut reference = ServeEngine::new(test_ctx("rt_a"), spec("calm"), 1).unwrap();
        reference.enqueue(admit("rclone", 2, None), Some(0)).unwrap();
        reference.enqueue(admit("2-phase", 2, Some(18)), Some(3)).unwrap();
        reference.enqueue(OpKind::Pause(0), Some(6)).unwrap();
        reference.enqueue(OpKind::Resume(0), Some(8)).unwrap();
        let head = run_lines(&mut reference, 10);
        let snap = reference.snapshot().unwrap();
        let tail_ref = run_lines(&mut reference, 14);

        let mut restored = ServeEngine::restore(test_ctx("rt_b"), snap, 1).unwrap();
        assert_eq!(restored.mi(), 10);
        let tail = run_lines(&mut restored, 14);
        assert_eq!(tail, tail_ref, "restored stream diverged from the uninterrupted run");
        assert!(!head.is_empty() && !tail.is_empty(), "workload produced no events");
    }

    #[test]
    fn schedule_expansion_queues_every_arrival() {
        let mut s = spec("chameleon");
        s.schedule = Some("churn-light".to_string());
        s.methods = vec!["rclone".to_string(), "2-phase".to_string()];
        let engine = ServeEngine::new(test_ctx("sched"), s, 1).unwrap();
        let sched = ArrivalSchedule::by_name("churn-light").unwrap();
        assert_eq!(engine.queue_len(), sched.arrivals_scaled(11, 1.0).len());
    }

    #[test]
    fn unknown_methods_are_rejected_at_enqueue() {
        let mut engine = ServeEngine::new(test_ctx("reject"), spec("calm"), 1).unwrap();
        let err = engine.enqueue(admit("no-such-method", 1, None), None);
        assert!(err.is_err(), "bogus method must be rejected");
        assert_eq!(engine.queue_len(), 0);
    }

    #[test]
    fn status_json_gates_the_fault_block() {
        // Fault-free service: no "faults" key at all.
        let mut plain = ServeEngine::new(test_ctx("fault_gate_a"), spec("calm"), 1).unwrap();
        plain.enqueue(admit("rclone", 1, None), Some(0)).unwrap();
        let mut events = Vec::new();
        plain.step(&mut events).unwrap();
        assert!(plain.status_json().get("faults").is_none());

        // Armed service: block present from boot, preset named, counters
        // climbing once the plan fires.
        let mut s = spec("calm");
        s.faults = Some("host-stall".to_string());
        let mut armed = ServeEngine::new(test_ctx("fault_gate_b"), s, 1).unwrap();
        // A job large enough to still be in flight when the stall window
        // opens (the plan's first stall lands at MI 12..21).
        armed.enqueue(admit("rclone", 4096, None), Some(0)).unwrap();
        let st = armed.status_json();
        let fb = st.get("faults").expect("armed service reports the fault block");
        assert_eq!(fb.get("preset").and_then(Json::as_str), Some("host-stall"));
        for _ in 0..30 {
            armed.step(&mut events).unwrap();
        }
        let st = armed.status_json();
        let fb = st.get("faults").unwrap();
        assert!(
            fb.get("faulted").and_then(Json::as_usize).unwrap() > 0,
            "host-stall plan never tripped the watchdog"
        );
    }

    #[test]
    fn status_json_reports_lane_table() {
        let mut engine = ServeEngine::new(test_ctx("status"), spec("calm"), 1).unwrap();
        engine.enqueue(admit("rclone", 1, None), Some(0)).unwrap();
        let mut events = Vec::new();
        for _ in 0..3 {
            engine.step(&mut events).unwrap();
        }
        let st = engine.status_json();
        assert_eq!(st.get("mi").and_then(Json::as_usize), Some(3));
        let lanes = st.get("lanes").and_then(Json::as_arr).unwrap();
        assert_eq!(lanes.len(), 1);
        assert_eq!(lanes[0].get("name").and_then(Json::as_str), Some("rclone#0"));
        assert_eq!(lanes[0].get("status").and_then(Json::as_str), Some("active"));
    }
}
