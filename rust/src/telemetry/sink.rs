//! Streaming event sinks for the step-driven session API.
//!
//! A [`crate::coordinator::Session`] emits [`Event`]s instead of
//! accumulating results internally; a [`TelemetrySink`] is anywhere those
//! events can go. [`ReportSink`] rebuilds the classic batch
//! [`RunReport`] from the stream (the compat path every pre-redesign
//! experiment runs through), [`FairnessSink`] accumulates epoch-bucketed
//! Jain's fairness incrementally (the fleet driver and `ReportSink` both
//! consume it), [`EventLog`] buffers raw events for tests and workload
//! drivers, and [`JsonlSink`] streams one JSON object per event — now
//! including attributed energy, per-rail breakdowns and paused markers —
//! to any writer (live dashboards, `--events` files).

use crate::coordinator::{Event, LaneReport, MiRecord, RunReport};
use crate::util::json::Json;
use crate::util::stats;
use std::io::Write;

/// Consumes the session event stream, one event at a time, in emission
/// order. Implementations must not assume they see a complete run — a
/// sink can be attached to any suffix of a session's life.
pub trait TelemetrySink {
    fn on_event(&mut self, event: &Event);
}

/// Drops every event (placeholder when only side effects matter).
#[derive(Debug, Clone, Copy, Default)]
pub struct NullSink;

impl TelemetrySink for NullSink {
    fn on_event(&mut self, _event: &Event) {}
}

/// Buffers the raw event stream (tests, workload drivers).
#[derive(Debug, Clone, Default)]
pub struct EventLog {
    pub events: Vec<Event>,
}

impl TelemetrySink for EventLog {
    fn on_event(&mut self, event: &Event) {
        self.events.push(event.clone());
    }
}

/// Streaming Jain's-fairness accumulator: epoch-bucketed per-lane
/// throughput means over the event stream, JFI per epoch over lanes active
/// in it.
///
/// This is the one shared implementation of the "skip epochs where no lane
/// was active, mean each lane's samples within the epoch" rule:
/// [`ReportSink`] uses it with `epoch_mis = 1` (the classic per-MI
/// `jfi_series`), and the fleet driver with its reporting epoch — the two
/// previously duplicated the logic. Paused lanes' zero-throughput
/// observation records are excluded: fairness is over lanes actually
/// competing for the bottleneck, with or without `observe_paused`.
#[derive(Debug, Clone)]
pub struct FairnessSink {
    epoch_mis: usize,
    /// `rows[epoch][lane] = (throughput sum, samples)`.
    rows: Vec<Vec<(f64, usize)>>,
}

impl Default for FairnessSink {
    fn default() -> Self {
        FairnessSink::new(1)
    }
}

impl FairnessSink {
    /// `epoch_mis` MIs per fairness bucket (1 = per-MI series).
    pub fn new(epoch_mis: usize) -> FairnessSink {
        assert!(epoch_mis >= 1, "FairnessSink epoch must be >= 1 MI");
        FairnessSink { epoch_mis, rows: Vec::new() }
    }

    /// JFI per epoch over lanes with samples in that epoch; epochs where no
    /// lane was active are skipped rather than scored as vacuously perfect.
    pub fn epoch_jfi(&self) -> Vec<f64> {
        self.rows
            .iter()
            .filter_map(|row| {
                let means: Vec<f64> = row
                    .iter()
                    .filter(|(_, n)| *n > 0)
                    .map(|(s, n)| s / *n as f64)
                    .collect();
                if means.is_empty() {
                    None
                } else {
                    Some(stats::jain_fairness(&means))
                }
            })
            .collect()
    }
}

impl TelemetrySink for FairnessSink {
    fn on_event(&mut self, event: &Event) {
        let Event::MiCompleted { lane, record } = event else {
            return;
        };
        if record.paused {
            return;
        }
        let e = record.mi / self.epoch_mis;
        while self.rows.len() <= e {
            self.rows.push(Vec::new());
        }
        let row = &mut self.rows[e];
        while row.len() <= lane.0 {
            row.push((0.0, 0));
        }
        row[lane.0].0 += record.throughput_gbps;
        row[lane.0].1 += 1;
    }
}

/// Per-lane accumulator behind [`ReportSink`].
#[derive(Debug, Clone, Default)]
struct LaneAcc {
    name: String,
    records: Vec<MiRecord>,
    completed: bool,
    /// Time of the lane's terminal event (None while still in flight).
    ended_at_s: Option<f64>,
    bytes_delivered: f64,
    total_energy_j: f64,
}

/// Rebuilds the batch-era [`RunReport`] from the event stream — the proof
/// that the old run-to-completion API is one sink over the new one.
/// Accumulation matches the pre-redesign controller bit-for-bit: records in
/// MI order per lane, lane totals from the meter/job running totals, and
/// the per-record-index Jain's-fairness series.
#[derive(Debug, Clone, Default)]
pub struct ReportSink {
    lanes: Vec<LaneAcc>,
    /// Per-MI fairness series, accumulated incrementally (epoch = 1 MI).
    fairness: FairnessSink,
}

impl ReportSink {
    pub fn new() -> ReportSink {
        ReportSink::default()
    }

    fn acc(&mut self, lane: usize) -> &mut LaneAcc {
        while self.lanes.len() <= lane {
            self.lanes.push(LaneAcc::default());
        }
        &mut self.lanes[lane]
    }

    /// Finalize into a [`RunReport`]. `duration_s` is the session's final
    /// simulated time; lanes without a terminal event report it as their
    /// duration (exactly as the batch controller reported unfinished lanes).
    pub fn finish(self, duration_s: f64) -> RunReport {
        let lanes: Vec<LaneReport> = self
            .lanes
            .into_iter()
            .map(|a| LaneReport {
                name: a.name,
                completed: a.completed,
                duration_s: a.ended_at_s.unwrap_or(duration_s),
                total_energy_j: a.total_energy_j,
                bytes_delivered: a.bytes_delivered,
                records: a.records,
            })
            .collect();
        // JFI per monitoring interval over lanes active in that MI, keyed
        // by `MiRecord.mi` so mid-run-admitted and paused lanes align on
        // concurrent samples; MIs where no lane was active are skipped
        // rather than reported as (vacuously) perfect fairness. The series
        // is accumulated incrementally by the shared [`FairnessSink`] with
        // a 1-MI epoch — each lane's single sample per MI divides by 1, so
        // on the batch path (all lanes admitted at MI 0, never paused) this
        // reproduces the pre-redesign per-index series bit-for-bit.
        let jfi_series = self.fairness.epoch_jfi();
        RunReport { lanes, duration_s, jfi_series }
    }
}

impl TelemetrySink for ReportSink {
    fn on_event(&mut self, event: &Event) {
        self.fairness.on_event(event);
        match event {
            Event::Admitted { lane, name, .. } => {
                self.acc(lane.0).name = name.to_string();
            }
            Event::MiCompleted { lane, record } => {
                let acc = self.acc(lane.0);
                acc.bytes_delivered = record.bytes_total;
                acc.total_energy_j = record.energy_total_j;
                acc.records.push(record.clone());
            }
            Event::Completed { lane, time_s, bytes_delivered, total_energy_j, .. } => {
                let acc = self.acc(lane.0);
                acc.completed = true;
                acc.ended_at_s = Some(*time_s);
                acc.bytes_delivered = *bytes_delivered;
                acc.total_energy_j = *total_energy_j;
            }
            Event::Departed { lane, time_s, bytes_delivered, total_energy_j, .. } => {
                let acc = self.acc(lane.0);
                acc.completed = false;
                acc.ended_at_s = Some(*time_s);
                acc.bytes_delivered = *bytes_delivered;
                acc.total_energy_j = *total_energy_j;
            }
            // Fault-plane lifecycle markers: no lane totals change at the
            // moment of faulting/retrying/migrating — the surrounding
            // MiCompleted records already carry the (zero-throughput)
            // story, exactly as for pause/resume.
            Event::Paused { .. }
            | Event::Resumed { .. }
            | Event::Faulted { .. }
            | Event::Retrying { .. }
            | Event::Migrated { .. } => {}
        }
    }
}

/// One JSON object per event (the per-MI `state` vector is omitted —
/// streams are for live monitoring, not for replaying learning).
pub fn event_json(event: &Event) -> Json {
    let head = |kind: &str, lane: usize, mi: usize, time_s: f64| {
        vec![
            ("event", Json::from(kind)),
            ("lane", Json::from(lane)),
            ("mi", Json::from(mi)),
            ("time_s", Json::from(time_s)),
        ]
    };
    match event {
        Event::Admitted { lane, name, mi, time_s } => {
            let mut o = head("admitted", lane.0, *mi, *time_s);
            o.push(("name", Json::from(&**name)));
            Json::obj(o)
        }
        Event::MiCompleted { lane, record } => {
            let mut o = head("mi", lane.0, record.mi, record.time_s);
            o.push(("throughput_gbps", Json::from(record.throughput_gbps)));
            o.push(("plr", Json::from(record.plr)));
            o.push(("rtt_s", Json::from(record.rtt_s)));
            o.push(("cc", Json::from(record.cc as usize)));
            o.push(("p", Json::from(record.p as usize)));
            o.push(("reward", Json::from(record.reward)));
            o.push(("bytes_total", Json::from(record.bytes_total)));
            // Attributed energy (omitted on testbeds without counters,
            // where the record carries NaN).
            if record.energy_j.is_finite() {
                o.push(("energy_j", Json::from(record.energy_j)));
                o.push(("energy_total_j", Json::from(record.energy_total_j)));
            }
            if record.paused {
                o.push(("paused", Json::from(true)));
            }
            // Per-rail breakdown (host-resolved accounting only).
            if let Some(r) = &record.rails {
                o.push(("energy_cpu_j", Json::from(r.cpu_j)));
                o.push(("energy_nic_j", Json::from(r.nic_j)));
                o.push(("energy_fixed_j", Json::from(r.fixed_j)));
                o.push(("energy_idle_j", Json::from(r.idle_j)));
            }
            Json::obj(o)
        }
        Event::Paused { lane, mi, time_s } => Json::obj(head("paused", lane.0, *mi, *time_s)),
        Event::Resumed { lane, mi, time_s } => Json::obj(head("resumed", lane.0, *mi, *time_s)),
        Event::Completed { lane, mi, time_s, bytes_delivered, total_energy_j } => {
            let mut o = head("completed", lane.0, *mi, *time_s);
            o.push(("bytes_delivered", Json::from(*bytes_delivered)));
            o.push(("total_energy_j", Json::from(*total_energy_j)));
            Json::obj(o)
        }
        Event::Departed { lane, mi, time_s, bytes_delivered, total_energy_j } => {
            let mut o = head("departed", lane.0, *mi, *time_s);
            o.push(("bytes_delivered", Json::from(*bytes_delivered)));
            o.push(("total_energy_j", Json::from(*total_energy_j)));
            Json::obj(o)
        }
        Event::Faulted { lane, mi, time_s, fault } => {
            let mut o = head("faulted", lane.0, *mi, *time_s);
            o.push(("fault", Json::from(*fault)));
            Json::obj(o)
        }
        Event::Retrying { lane, mi, time_s, attempt } => {
            let mut o = head("retrying", lane.0, *mi, *time_s);
            o.push(("attempt", Json::from(*attempt as usize)));
            Json::obj(o)
        }
        Event::Migrated { lane, mi, time_s, from_host, to_host } => {
            let mut o = head("migrated", lane.0, *mi, *time_s);
            o.push(("from_host", Json::from(*from_host)));
            o.push(("to_host", Json::from(*to_host)));
            Json::obj(o)
        }
    }
}

/// Fans one event stream out to several sinks, in order (e.g. a
/// [`ReportSink`] plus a [`JsonlSink`] on the same session).
pub struct FanoutSink<'a> {
    pub sinks: Vec<&'a mut dyn TelemetrySink>,
}

impl TelemetrySink for FanoutSink<'_> {
    fn on_event(&mut self, event: &Event) {
        for sink in self.sinks.iter_mut() {
            sink.on_event(event);
        }
    }
}

/// Streams events as JSON lines to any writer (files, pipes, sockets).
///
/// I/O failure (disk full, closed pipe) must never *panic* a transfer,
/// but it must not be silent either: the first write/flush error is
/// recorded sticky, further output is suppressed, and the owning driver
/// surfaces it as a run-level error via [`JsonlSink::io_error`] /
/// [`JsonlSink::take_error`] — `sparta transfer` and the serve pacer both
/// fail the run (with events intact up to the failure point) instead of
/// dropping the rest of the stream on the floor.
///
/// The writer is flushed on drop (and on [`JsonlSink::flush`]), so a sink
/// over a `BufWriter` that goes out of scope mid-run — a daemon shutting
/// down, a driver bailing on error — leaves no buffered tail behind.
///
/// §Perf: each event is formatted into a reusable `String` and handed to
/// the writer as one `write_all` — no per-event buffer allocation, and no
/// `Display`-adapter round trips through the writer's fine-grained
/// `write_fmt` machinery.
pub struct JsonlSink<W: Write> {
    /// `None` only after `into_inner` moved the writer out (the `Drop`
    /// impl forbids a plain field move).
    out: Option<W>,
    /// Reusable line buffer.
    buf: String,
    /// First write/flush error, held until the driver collects it.
    err: Option<std::io::Error>,
}

impl<W: Write> JsonlSink<W> {
    pub fn new(out: W) -> JsonlSink<W> {
        JsonlSink { out: Some(out), buf: String::new(), err: None }
    }

    /// Flush the underlying writer; a failure is recorded like a write
    /// failure.
    pub fn flush(&mut self) {
        if let Some(out) = &mut self.out {
            if let Err(e) = out.flush() {
                if self.err.is_none() {
                    self.err = Some(e);
                }
            }
        }
    }

    /// The first I/O error the sink hit, if any. Once set, no further
    /// events are written; the driver should abort the run with it.
    pub fn io_error(&self) -> Option<&std::io::Error> {
        self.err.as_ref()
    }

    /// Take the first I/O error out of the sink (for propagation).
    pub fn take_error(&mut self) -> Option<std::io::Error> {
        self.err.take()
    }

    /// Recover the writer without flushing (the caller owns it again and
    /// decides — e.g. `sparta transfer` flushes the `BufWriter` itself).
    pub fn into_inner(mut self) -> W {
        self.out.take().expect("writer already taken")
    }
}

impl<W: Write> Drop for JsonlSink<W> {
    fn drop(&mut self) {
        self.flush();
    }
}

impl<W: Write> TelemetrySink for JsonlSink<W> {
    fn on_event(&mut self, event: &Event) {
        use std::fmt::Write as _;
        if self.err.is_some() {
            return;
        }
        self.buf.clear();
        let _ = write!(self.buf, "{}", event_json(event));
        self.buf.push('\n');
        if let Some(out) = &mut self.out {
            if let Err(e) = out.write_all(self.buf.as_bytes()) {
                self.err = Some(e);
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::LaneId;

    fn record(mi: usize, thr: f64, bytes: f64) -> MiRecord {
        MiRecord {
            mi,
            time_s: (mi + 1) as f64,
            throughput_gbps: thr,
            plr: 0.0,
            rtt_s: 0.03,
            energy_j: 40.0,
            cc: 4,
            p: 4,
            metric: thr,
            reward: 0.5,
            action: None,
            state: vec![0.0; 4],
            bytes_total: bytes,
            energy_total_j: 40.0 * (mi + 1) as f64,
            paused: false,
            rails: None,
        }
    }

    fn mi_event(lane: usize, rec: MiRecord) -> Event {
        Event::MiCompleted { lane: LaneId(lane), record: rec }
    }

    #[test]
    fn report_sink_rebuilds_lane_totals() {
        let mut sink = ReportSink::new();
        sink.on_event(&Event::Admitted {
            lane: LaneId(0),
            name: "tool".into(),
            mi: 0,
            time_s: 0.0,
        });
        sink.on_event(&Event::MiCompleted { lane: LaneId(0), record: record(0, 4.0, 1e9) });
        sink.on_event(&Event::MiCompleted { lane: LaneId(0), record: record(1, 6.0, 2e9) });
        sink.on_event(&Event::Completed {
            lane: LaneId(0),
            mi: 1,
            time_s: 2.0,
            bytes_delivered: 2e9,
            total_energy_j: 80.0,
        });
        let report = sink.finish(2.0);
        let lane = report.lane();
        assert_eq!(lane.name, "tool");
        assert!(lane.completed);
        assert_eq!(lane.records.len(), 2);
        assert_eq!(lane.duration_s, 2.0);
        assert_eq!(lane.bytes_delivered, 2e9);
        assert_eq!(lane.total_energy_j, 80.0);
        assert_eq!(report.jfi_series.len(), 2);
    }

    #[test]
    fn unfinished_lane_uses_session_duration() {
        let mut sink = ReportSink::new();
        sink.on_event(&Event::Admitted {
            lane: LaneId(0),
            name: "slow".into(),
            mi: 0,
            time_s: 0.0,
        });
        sink.on_event(&Event::MiCompleted { lane: LaneId(0), record: record(0, 1.0, 1e8) });
        let report = sink.finish(9.5);
        assert!(!report.lane().completed);
        assert_eq!(report.lane().duration_s, 9.5);
        assert_eq!(report.lane().bytes_delivered, 1e8);
    }

    /// The fairness series aligns lanes by `MiRecord.mi`, not by record
    /// index: a lane admitted mid-run only joins the JFI at the MIs it was
    /// actually concurrent for.
    #[test]
    fn jfi_series_aligns_by_monitoring_interval() {
        let mut sink = ReportSink::new();
        for (lane, mis) in [(0usize, vec![0, 1, 2]), (1usize, vec![2, 3])] {
            sink.on_event(&Event::Admitted {
                lane: LaneId(lane),
                name: format!("l{lane}").into(),
                mi: mis[0],
                time_s: mis[0] as f64,
            });
            for mi in mis {
                sink.on_event(&Event::MiCompleted {
                    lane: LaneId(lane),
                    // Lane 1 runs at half lane 0's throughput where they
                    // overlap (MI 2), so JFI dips exactly there.
                    record: record(mi, if lane == 0 { 4.0 } else { 2.0 }, 1e9),
                });
            }
        }
        let report = sink.finish(4.0);
        assert_eq!(report.jfi_series.len(), 4); // MIs 0..=3
        assert_eq!(report.jfi_series[0], 1.0); // lane 0 alone
        assert_eq!(report.jfi_series[1], 1.0);
        assert!(report.jfi_series[2] < 1.0); // both lanes, unequal shares
        assert_eq!(report.jfi_series[3], 1.0); // lane 1 alone
    }

    /// The fairness sink buckets per-lane throughput means by epoch and
    /// skips epochs with no active lane.
    #[test]
    fn fairness_sink_buckets_by_epoch() {
        let mut sink = FairnessSink::new(2);
        // Epoch 0 (MIs 0-1): lane 0 alone. Epoch 2 (MIs 4-5): both lanes,
        // unequal. Epoch 1 empty -> skipped.
        sink.on_event(&mi_event(0, record(0, 4.0, 1e9)));
        sink.on_event(&mi_event(0, record(1, 4.0, 2e9)));
        sink.on_event(&mi_event(0, record(4, 6.0, 3e9)));
        sink.on_event(&mi_event(1, record(4, 2.0, 1e9)));
        sink.on_event(&mi_event(1, record(5, 2.0, 2e9)));
        let jfi = sink.epoch_jfi();
        assert_eq!(jfi.len(), 2, "empty epoch must be skipped: {jfi:?}");
        assert_eq!(jfi[0], 1.0);
        assert!(jfi[1] < 1.0);
    }

    /// Paused lanes' zero-throughput observation records do not count as
    /// starved lanes in the fairness series.
    #[test]
    fn fairness_sink_excludes_paused_records() {
        let mut with_paused = FairnessSink::new(1);
        let mut without = FairnessSink::new(1);
        let active = record(0, 4.0, 1e9);
        let paused = MiRecord { throughput_gbps: 0.0, paused: true, ..record(0, 0.0, 0.0) };
        with_paused.on_event(&mi_event(0, active.clone()));
        with_paused.on_event(&mi_event(1, paused));
        without.on_event(&mi_event(0, active));
        assert_eq!(with_paused.epoch_jfi(), without.epoch_jfi());
        assert_eq!(with_paused.epoch_jfi(), vec![1.0]);
    }

    /// Dropping the sink flushes the writer exactly once — a daemon (or a
    /// driver bailing on error) that lets a `JsonlSink<BufWriter<_>>` go
    /// out of scope leaves no buffered tail behind. `into_inner` hands the
    /// unflushed writer back instead (the caller owns the flush).
    #[test]
    fn jsonl_sink_flushes_writer_on_drop() {
        use std::sync::{Arc, Mutex};
        struct CountingWriter {
            bytes: Arc<Mutex<Vec<u8>>>,
            flushes: Arc<Mutex<usize>>,
        }
        impl Write for CountingWriter {
            fn write(&mut self, buf: &[u8]) -> std::io::Result<usize> {
                self.bytes.lock().unwrap().extend_from_slice(buf);
                Ok(buf.len())
            }
            fn flush(&mut self) -> std::io::Result<()> {
                *self.flushes.lock().unwrap() += 1;
                Ok(())
            }
        }
        let bytes = Arc::new(Mutex::new(Vec::new()));
        let flushes = Arc::new(Mutex::new(0));
        {
            let mut sink = JsonlSink::new(CountingWriter {
                bytes: Arc::clone(&bytes),
                flushes: Arc::clone(&flushes),
            });
            sink.on_event(&Event::Admitted {
                lane: LaneId(0),
                name: "x".into(),
                mi: 0,
                time_s: 0.0,
            });
            assert_eq!(*flushes.lock().unwrap(), 0, "no flush before drop");
        }
        assert_eq!(*flushes.lock().unwrap(), 1, "drop must flush exactly once");
        assert_eq!(String::from_utf8(bytes.lock().unwrap().clone()).unwrap().lines().count(), 1);
        // The into_inner path: the writer comes back unflushed.
        let mut sink = JsonlSink::new(CountingWriter {
            bytes: Arc::clone(&bytes),
            flushes: Arc::clone(&flushes),
        });
        sink.on_event(&Event::Paused { lane: LaneId(0), mi: 1, time_s: 1.0 });
        let _w = sink.into_inner();
        assert_eq!(*flushes.lock().unwrap(), 1, "into_inner must not flush");
    }

    /// A failing writer (disk full, closed pipe) surfaces as a sticky
    /// run-level error instead of silently dropping the rest of the
    /// stream — and the sink stops writing after the first failure.
    #[test]
    fn jsonl_sink_surfaces_write_errors() {
        struct FailingWriter {
            ok_writes: usize,
            attempts: usize,
        }
        impl Write for FailingWriter {
            fn write(&mut self, buf: &[u8]) -> std::io::Result<usize> {
                self.attempts += 1;
                if self.attempts <= self.ok_writes {
                    Ok(buf.len())
                } else {
                    Err(std::io::Error::new(std::io::ErrorKind::WriteZero, "disk full"))
                }
            }
            fn flush(&mut self) -> std::io::Result<()> {
                Ok(())
            }
        }
        let mut sink = JsonlSink::new(FailingWriter { ok_writes: 1, attempts: 0 });
        let admitted = Event::Admitted { lane: LaneId(0), name: "x".into(), mi: 0, time_s: 0.0 };
        sink.on_event(&admitted);
        assert!(sink.io_error().is_none(), "first write succeeds");
        sink.on_event(&admitted);
        assert!(sink.io_error().is_some(), "second write must record the error");
        sink.on_event(&admitted);
        let attempts = {
            let e = sink.take_error().expect("error is takeable");
            assert_eq!(e.kind(), std::io::ErrorKind::WriteZero);
            sink.into_inner().attempts
        };
        assert_eq!(attempts, 2, "no further writes after the first failure");
    }

    #[test]
    fn jsonl_sink_streams_one_line_per_event() {
        let mut sink = JsonlSink::new(Vec::new());
        sink.on_event(&Event::Admitted {
            lane: LaneId(0),
            name: "x".into(),
            mi: 0,
            time_s: 0.0,
        });
        sink.on_event(&Event::MiCompleted { lane: LaneId(0), record: record(0, 4.0, 1e9) });
        let text = String::from_utf8(sink.into_inner()).unwrap();
        let lines: Vec<&str> = text.lines().collect();
        assert_eq!(lines.len(), 2);
        let first = Json::parse(lines[0]).unwrap();
        assert_eq!(first.get("event").unwrap().as_str(), Some("admitted"));
        let second = Json::parse(lines[1]).unwrap();
        assert_eq!(second.get("event").unwrap().as_str(), Some("mi"));
        assert_eq!(second.get("throughput_gbps").unwrap().as_f64(), Some(4.0));
    }
}
