//! Machine-readable experiment reports (JSON files under `data/reports/`).

use crate::util::json::Json;
use anyhow::{Context, Result};
use std::path::Path;

/// Write a JSON report, creating parent directories.
pub fn save_report(path: &Path, value: &Json) -> Result<()> {
    if let Some(parent) = path.parent() {
        std::fs::create_dir_all(parent)
            .with_context(|| format!("creating {}", parent.display()))?;
    }
    std::fs::write(path, value.to_string()).with_context(|| format!("writing {}", path.display()))
}

/// Build a JSON summary of a [`crate::coordinator::LaneReport`]. On the
/// lumped compat rail the fields (and bytes) are unchanged from the
/// pre-refactor format; host-resolved lanes additionally carry their
/// per-rail energy rollup.
pub fn lane_json(lane: &crate::coordinator::LaneReport) -> Json {
    let mut o = vec![
        ("name", Json::from(lane.name.clone())),
        ("completed", Json::from(lane.completed)),
        ("duration_s", Json::from(lane.duration_s)),
        ("avg_throughput_gbps", Json::from(lane.avg_throughput_gbps())),
        ("total_energy_j", Json::from(lane.total_energy_j)),
        ("energy_per_gb_j", Json::from(lane.energy_per_gb())),
        ("avg_plr", Json::from(lane.avg_plr())),
        ("bytes_delivered", Json::from(lane.bytes_delivered)),
        ("mis", Json::from(lane.records.len())),
    ];
    if let Some(r) = lane.rail_totals() {
        o.push(("energy_cpu_j", Json::from(r.cpu_j)));
        o.push(("energy_nic_j", Json::from(r.nic_j)));
        o.push(("energy_fixed_j", Json::from(r.fixed_j)));
        o.push(("energy_idle_j", Json::from(r.idle_j)));
    }
    Json::obj(o)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn saves_and_parses_back() {
        let path = std::env::temp_dir().join("sparta_report_test/r.json");
        let j = Json::obj(vec![("x", Json::from(1.5))]);
        save_report(&path, &j).unwrap();
        let text = std::fs::read_to_string(&path).unwrap();
        let back = Json::parse(&text).unwrap();
        assert_eq!(back.get("x").unwrap().as_f64(), Some(1.5));
    }
}
