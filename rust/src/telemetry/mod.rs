//! Experiment telemetry: streaming event sinks, tables and
//! machine-readable reports.
//!
//! The session API streams [`crate::coordinator::Event`]s into a
//! [`TelemetrySink`] instead of accumulating results inside the
//! coordinator; [`ReportSink`] rebuilds the classic batch
//! [`crate::coordinator::RunReport`] from that stream.

pub mod report;
pub mod sink;
pub mod table;

pub use report::save_report;
pub use sink::{
    event_json, EventLog, FairnessSink, FanoutSink, JsonlSink, NullSink, ReportSink,
    TelemetrySink,
};
pub use table::Table;
