//! Experiment telemetry: tables, timelines and machine-readable reports.

pub mod report;
pub mod table;

pub use report::save_report;
pub use table::Table;
