//! Fixed-width ASCII tables for the bench harness (criterion is unavailable
//! offline; the benches print paper-style tables instead).

/// A simple left-aligned text table.
#[derive(Debug, Clone, Default)]
pub struct Table {
    header: Vec<String>,
    rows: Vec<Vec<String>>,
}

impl Table {
    pub fn new(header: &[&str]) -> Table {
        Table { header: header.iter().map(|s| s.to_string()).collect(), rows: Vec::new() }
    }

    pub fn row(&mut self, cells: Vec<String>) -> &mut Self {
        assert_eq!(cells.len(), self.header.len(), "row width != header width");
        self.rows.push(cells);
        self
    }

    pub fn render(&self) -> String {
        let ncols = self.header.len();
        let mut widths: Vec<usize> = self.header.iter().map(|h| h.len()).collect();
        for row in &self.rows {
            for (i, c) in row.iter().enumerate() {
                widths[i] = widths[i].max(c.len());
            }
        }
        let sep: String = widths
            .iter()
            .map(|w| "-".repeat(w + 2))
            .collect::<Vec<_>>()
            .join("+");
        let fmt_row = |cells: &[String]| -> String {
            (0..ncols)
                .map(|i| format!(" {:<width$} ", cells[i], width = widths[i]))
                .collect::<Vec<_>>()
                .join("|")
        };
        let mut out = String::new();
        out.push_str(&fmt_row(&self.header));
        out.push('\n');
        out.push_str(&sep);
        out.push('\n');
        for row in &self.rows {
            out.push_str(&fmt_row(row));
            out.push('\n');
        }
        out
    }

    pub fn print(&self) {
        print!("{}", self.render());
    }
}

/// Format helper: fixed 2-decimal float.
pub fn f2(x: f64) -> String {
    format!("{x:.2}")
}

/// Format helper: fixed 3-decimal float.
pub fn f3(x: f64) -> String {
    format!("{x:.3}")
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn renders_aligned() {
        let mut t = Table::new(&["method", "gbps"]);
        t.row(vec!["rclone".into(), "4.52".into()]);
        t.row(vec!["sparta-fe".into(), "9.81".into()]);
        let s = t.render();
        let lines: Vec<&str> = s.lines().collect();
        assert_eq!(lines.len(), 4);
        // All lines equal width.
        assert!(lines.iter().all(|l| l.len() == lines[0].len()));
        assert!(s.contains("sparta-fe"));
    }

    #[test]
    #[should_panic]
    fn wrong_width_panics() {
        let mut t = Table::new(&["a", "b"]);
        t.row(vec!["only-one".into()]);
    }
}
