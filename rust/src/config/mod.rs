//! Run-time configuration: directory layout and experiment defaults.
//!
//! Everything is overridable from the CLI; environment variable
//! `SPARTA_ROOT` relocates the whole tree (useful for tests and benches).

use std::path::PathBuf;

/// Directory layout of a SPARTA deployment.
#[derive(Debug, Clone)]
pub struct Paths {
    /// AOT artifacts (HLO text + manifest + init params).
    pub artifacts: PathBuf,
    /// Mutable data: transition logs, trained weights, reports.
    pub data: PathBuf,
}

impl Paths {
    /// Resolve against `SPARTA_ROOT` (or the current directory).
    pub fn resolve() -> Paths {
        let root = std::env::var_os("SPARTA_ROOT")
            .map(PathBuf::from)
            .unwrap_or_else(|| PathBuf::from("."));
        Paths { artifacts: root.join("artifacts"), data: root.join("data") }
    }

    pub fn with_root(root: impl Into<PathBuf>) -> Paths {
        let root = root.into();
        Paths { artifacts: root.join("artifacts"), data: root.join("data") }
    }

    /// Trained-weights directory.
    pub fn weights(&self) -> PathBuf {
        self.data.join("weights")
    }

    /// Transition-log directory.
    pub fn transitions(&self) -> PathBuf {
        self.data.join("transitions")
    }

    /// Experiment-report directory.
    pub fn reports(&self) -> PathBuf {
        self.data.join("reports")
    }
}

/// Experiment defaults shared by the CLI and the bench harness.
#[derive(Debug, Clone)]
pub struct Defaults {
    /// Monitoring-interval length, seconds.
    pub mi_s: f64,
    /// State-window length n.
    pub history: usize,
    /// Default evaluation workload: files × bytes.
    pub eval_files: usize,
    pub eval_file_bytes: u64,
    /// Trials per evaluation point (the paper repeats 5×).
    pub trials: usize,
}

impl Default for Defaults {
    fn default() -> Self {
        Defaults {
            mi_s: 1.0,
            history: 8,
            eval_files: 1000,
            eval_file_bytes: 1 << 30,
            trials: 5,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn with_root_layout() {
        let p = Paths::with_root("/tmp/x");
        assert_eq!(p.artifacts, PathBuf::from("/tmp/x/artifacts"));
        assert_eq!(p.weights(), PathBuf::from("/tmp/x/data/weights"));
        assert_eq!(p.transitions(), PathBuf::from("/tmp/x/data/transitions"));
    }

    #[test]
    fn defaults_match_paper_workload() {
        let d = Defaults::default();
        assert_eq!(d.eval_files, 1000);
        assert_eq!(d.eval_file_bytes, 1 << 30);
        assert_eq!(d.trials, 5);
    }
}
