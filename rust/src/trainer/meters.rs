//! Process resource meters behind the Table-1 columns.
//!
//! CPU% and memory% come from `/proc/self` (Linux); the "GPU%" column of the
//! paper maps to the XLA-executable share of wall time (the accelerator-side
//! work in this CPU-only reproduction). Training energy uses a documented
//! host power model: `P = 45 W + 120 W × cpu_utilization` — the same
//! baseline-subtracted view RAPL would give on the paper's nodes.

use std::time::Instant;

/// Snapshot-based meter over the current process.
pub struct ResourceMeter {
    wall_start: Instant,
    cpu_start_s: f64,
    ncores: f64,
}

/// Readings accumulated between `start()` and `stop()`.
#[derive(Debug, Clone)]
pub struct MeterReading {
    pub wall_s: f64,
    pub cpu_s: f64,
    /// Process CPU utilization of one core, percent (can exceed 100 with
    /// threads; matches what `top` reports).
    pub cpu_pct: f64,
    /// Resident set size as a share of system memory, percent.
    pub mem_pct: f64,
    /// Estimated training energy, kJ (host power model).
    pub energy_kj: f64,
}

impl ResourceMeter {
    pub fn start() -> ResourceMeter {
        ResourceMeter {
            wall_start: Instant::now(),
            cpu_start_s: proc_cpu_seconds().unwrap_or(0.0),
            ncores: std::thread::available_parallelism().map(|n| n.get() as f64).unwrap_or(1.0),
        }
    }

    pub fn stop(&self) -> MeterReading {
        let wall_s = self.wall_start.elapsed().as_secs_f64().max(1e-9);
        let cpu_s = (proc_cpu_seconds().unwrap_or(0.0) - self.cpu_start_s).max(0.0);
        let cpu_pct = 100.0 * cpu_s / wall_s;
        let mem_pct = mem_percent().unwrap_or(0.0);
        // Host power model (see module docs); utilization normalized to the
        // machine, clamped to [0, 1].
        let util = (cpu_s / (wall_s * self.ncores)).clamp(0.0, 1.0);
        let energy_kj = wall_s * (45.0 + 120.0 * util) / 1000.0;
        MeterReading { wall_s, cpu_s, cpu_pct, mem_pct, energy_kj }
    }
}

/// utime + stime of this process, in seconds.
fn proc_cpu_seconds() -> Option<f64> {
    let stat = std::fs::read_to_string("/proc/self/stat").ok()?;
    // Skip past the parenthesized comm field (may contain spaces), then
    // utime/stime are the 12th/13th remaining fields (fields 14/15 overall).
    let after = &stat[stat.rfind(')')? + 1..];
    let parts: Vec<&str> = after.split_whitespace().collect();
    let utime: f64 = parts.get(11)?.parse().ok()?;
    let stime: f64 = parts.get(12)?.parse().ok()?;
    let hz = 100.0; // USER_HZ on all supported platforms
    Some((utime + stime) / hz)
}

/// Resident set size / MemTotal, percent.
fn mem_percent() -> Option<f64> {
    let statm = std::fs::read_to_string("/proc/self/statm").ok()?;
    let rss_pages: f64 = statm.split_whitespace().nth(1)?.parse().ok()?;
    let meminfo = std::fs::read_to_string("/proc/meminfo").ok()?;
    let total_kb: f64 = meminfo
        .lines()
        .find(|l| l.starts_with("MemTotal:"))?
        .split_whitespace()
        .nth(1)?
        .parse()
        .ok()?;
    let page_kb = 4.0;
    Some(100.0 * rss_pages * page_kb / total_kb)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn meter_measures_busy_work() {
        let m = ResourceMeter::start();
        // Burn ~30 ms of CPU.
        let mut acc = 0u64;
        let t0 = Instant::now();
        while t0.elapsed().as_millis() < 30 {
            acc = acc.wrapping_mul(6364136223846793005).wrapping_add(1);
        }
        std::hint::black_box(acc);
        let r = m.stop();
        assert!(r.wall_s >= 0.03);
        assert!(r.cpu_s > 0.0, "cpu_s={}", r.cpu_s);
        assert!(r.cpu_pct > 10.0, "cpu_pct={}", r.cpu_pct);
        assert!(r.energy_kj > 0.0);
    }

    #[test]
    fn mem_percent_readable() {
        let p = mem_percent().unwrap();
        assert!(p > 0.0 && p < 100.0, "mem%={p}");
    }
}
