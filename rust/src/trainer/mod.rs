//! Training drivers: exploration-phase collection, offline (emulated)
//! training, online tuning, and the resource meters behind Table 1.
//!
//! The paper's offline-online process (Fig. 2):
//! 1. [`explore::collect_transitions`] runs high-exploration transfers on
//!    the live substrate and logs per-MI transitions;
//! 2. the transitions are clustered into a [`crate::emulator::ClusterEnv`];
//! 3. [`offline::train_offline`] trains each agent against the emulator;
//! 4. the trained policy is validated/tuned on the live substrate
//!    ([`live_env::LiveEnv`], used by the Fig.-5 experiment).

pub mod explore;
pub mod live_env;
pub mod meters;
pub mod offline;

pub use explore::{collect_transitions, collect_transitions_scenario, ExplorePolicy};
pub use live_env::LiveEnv;
pub use meters::ResourceMeter;
pub use offline::{train_offline, TrainConfig, TrainStats};
