//! Live training environment: the network substrate exposed through the
//! [`Env`] interface (used for online tuning — Fig. 5 — and for validating
//! emulator-trained policies against "real" dynamics — Fig. 4 bottom row).
//! Episodes run against any [`Substrate`] — the testbed's single bottleneck
//! by default, or a scenario's multi-segment topology.

use crate::coordinator::{
    FeatureWindow, Observation, ParamBounds, RewardConfig, RewardKind, RewardTracker,
};
use crate::emulator::{Env, StepOut};
use crate::energy::{EnergyMeter, PowerModel};
use crate::net::{FlowId, MiMetrics, NetworkSim, Substrate, Testbed, Topology};
use crate::scenarios::Scenario;
use crate::util::Rng;

/// A fixed-horizon episodic environment over the live substrate.
pub struct LiveEnv {
    testbed: Testbed,
    topology: Option<Topology>,
    bounds: ParamBounds,
    reward_kind: RewardKind,
    history: usize,
    episode_len: usize,
    mi_s: f64,
    rng: Rng,
    // Episode state.
    sim: Option<Box<dyn Substrate>>,
    /// Reusable per-MI metrics buffer (§Perf: the training loop never
    /// allocates per observation).
    metrics: Vec<MiMetrics>,
    flow: FlowId,
    meter: EnergyMeter,
    window: FeatureWindow,
    tracker: RewardTracker,
    cc: u32,
    p: u32,
    steps: usize,
}

impl LiveEnv {
    pub fn new(
        testbed: Testbed,
        reward_kind: RewardKind,
        bounds: ParamBounds,
        history: usize,
        episode_len: usize,
        seed: u64,
    ) -> LiveEnv {
        let window = FeatureWindow::new(history, bounds.cc_max, bounds.p_max);
        LiveEnv {
            testbed,
            topology: None,
            bounds,
            reward_kind,
            history,
            episode_len,
            mi_s: 1.0,
            rng: Rng::new(seed),
            sim: None,
            metrics: Vec::new(),
            flow: FlowId(0),
            meter: EnergyMeter::new(PowerModel::efficient(), seed),
            window,
            tracker: RewardTracker::new(reward_kind, RewardConfig::default()),
            cc: 4,
            p: 4,
            steps: 0,
        }
    }

    /// An environment whose episodes run under a registered scenario's
    /// topology and cross traffic instead of the bare testbed.
    pub fn for_scenario(
        scenario: &Scenario,
        reward_kind: RewardKind,
        bounds: ParamBounds,
        history: usize,
        episode_len: usize,
        seed: u64,
    ) -> LiveEnv {
        let mut env = LiveEnv::new(
            scenario.testbed.clone(),
            reward_kind,
            bounds,
            history,
            episode_len,
            seed,
        );
        env.topology = Some(scenario.topology.clone());
        env
    }

    fn observe_mi(&mut self) -> Observation {
        let sim = self.sim.as_mut().unwrap();
        // §Perf: reuse one metrics buffer across the whole training run.
        sim.run_mi_into(self.mi_s, &mut self.metrics);
        let m = &self.metrics[self.flow.0];
        let energy = if self.testbed.has_energy_counters {
            self.meter.record_mi(m.active_streams, m.throughput_gbps, m.duration_s)
        } else {
            f64::NAN
        };
        Observation {
            throughput_gbps: m.throughput_gbps,
            plr: m.plr,
            rtt_s: m.rtt_s,
            energy_j: energy,
            cc: self.cc,
            p: self.p,
            duration_s: m.duration_s,
        }
    }

    /// Throughput/energy of the last MI (telemetry convenience).
    pub fn testbed(&self) -> &Testbed {
        &self.testbed
    }
}

impl Env for LiveEnv {
    fn reset(&mut self) -> Vec<f32> {
        let seed = self.rng.next_u64();
        let mut sim: Box<dyn Substrate> = match &self.topology {
            Some(t) => Box::new(NetworkSim::from_topology(self.testbed.clone(), t, seed)),
            None => Box::new(NetworkSim::new(self.testbed.clone(), seed)),
        };
        self.cc = self.bounds.cc0;
        self.p = self.bounds.p0;
        self.flow = sim.add_flow(self.cc, self.p, None);
        self.sim = Some(sim);
        self.meter = EnergyMeter::new(PowerModel::efficient(), seed ^ 0xEE);
        self.window = FeatureWindow::new(self.history, self.bounds.cc_max, self.bounds.p_max);
        self.tracker = RewardTracker::new(self.reward_kind, RewardConfig::default());
        self.steps = 0;
        // Warm up past slow-start so episode starts see steady dynamics.
        for _ in 0..3 {
            let obs = self.observe_mi();
            self.window.push(&obs);
            self.tracker.update(&obs);
        }
        self.window.state().to_vec()
    }

    fn step(&mut self, action: usize) -> StepOut {
        let (cc, p) = self.bounds.apply(self.cc, self.p, action);
        if (cc, p) != (self.cc, self.p) {
            self.cc = cc;
            self.p = p;
            self.sim.as_mut().unwrap().set_cc_p(self.flow, cc, p);
        }
        let obs = self.observe_mi();
        self.window.push(&obs);
        let out = self.tracker.update(&obs);
        self.steps += 1;
        StepOut {
            state: self.window.state().to_vec(),
            reward: out.reward,
            done: self.steps >= self.episode_len,
            throughput_gbps: obs.throughput_gbps,
            energy_j: obs.energy_j,
        }
    }

    fn state_len(&self) -> usize {
        self.window.state_len()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn episodes_run_and_terminate() {
        let mut env = LiveEnv::new(
            Testbed::chameleon(),
            RewardKind::ThroughputEnergy,
            ParamBounds::default(),
            8,
            20,
            3,
        );
        let s = env.reset();
        assert_eq!(s.len(), env.state_len());
        let mut done = false;
        let mut total_thr = 0.0;
        for _ in 0..20 {
            let out = env.step(1);
            done = out.done;
            total_thr += out.throughput_gbps;
        }
        assert!(done);
        assert!(total_thr > 0.0);
    }

    #[test]
    fn scenario_episodes_respect_bottleneck() {
        let sc = Scenario::by_name("nic-limited").unwrap();
        let mut env = LiveEnv::for_scenario(
            &sc,
            RewardKind::ThroughputEnergy,
            ParamBounds::default(),
            4,
            10,
            7,
        );
        env.reset();
        let mut peak: f64 = 0.0;
        for _ in 0..10 {
            let out = env.step(1);
            peak = peak.max(out.throughput_gbps);
        }
        // The scenario's 4 Gbps sender NIC caps goodput on a 10 Gbps WAN.
        assert!(peak > 0.0);
        assert!(peak <= 4.0 + 1e-6, "peak={peak}");
    }

    #[test]
    fn increasing_actions_grow_streams() {
        let mut env = LiveEnv::new(
            Testbed::chameleon(),
            RewardKind::FairnessEfficiency,
            ParamBounds::default(),
            4,
            50,
            5,
        );
        env.reset();
        for _ in 0..6 {
            env.step(3); // +2/+2 each MI
        }
        assert_eq!((env.cc, env.p), (16, 16));
    }
}
