//! Exploration-phase transition collection (§3.4 step 1).

use crate::coordinator::{
    Controller, Decision, MiContext, Optimizer, ParamBounds, RewardKind,
};
use crate::emulator::{transitions_from_records, Transition};
use crate::net::background::Background;
use crate::net::Testbed;
use crate::scenarios::Scenario;
use crate::transfer::{EngineProfile, TransferJob};
use crate::util::Rng;

/// High-exploration policy: walks toward random (cc, p) way-points using the
/// five-action space, with a floor of uniformly random actions. Covers the
/// parameter grid with *labeled* actions — exactly what the cluster-lookup
/// emulator needs.
pub struct ExplorePolicy {
    rng: Rng,
    target: (u32, u32),
    retarget_in: usize,
    /// Probability of a uniformly random action.
    pub random_frac: f64,
}

impl ExplorePolicy {
    pub fn new(seed: u64) -> ExplorePolicy {
        ExplorePolicy { rng: Rng::new(seed), target: (4, 4), retarget_in: 0, random_frac: 0.3 }
    }

    fn retarget(&mut self, bounds: &ParamBounds) {
        self.target = (
            bounds.cc_min + self.rng.below((bounds.cc_max - bounds.cc_min + 1) as usize) as u32,
            bounds.p_min + self.rng.below((bounds.p_max - bounds.p_min + 1) as usize) as u32,
        );
        self.retarget_in = 8 + self.rng.below(16);
    }
}

impl Optimizer for ExplorePolicy {
    fn name(&self) -> &str {
        "explore"
    }

    fn start(&mut self, bounds: &ParamBounds) -> (u32, u32) {
        self.retarget(bounds);
        (
            bounds.cc_min + self.rng.below((bounds.cc_max - bounds.cc_min + 1) as usize) as u32,
            bounds.p_min + self.rng.below((bounds.p_max - bounds.p_min + 1) as usize) as u32,
        )
    }

    fn decide(&mut self, ctx: &MiContext<'_>) -> Decision {
        if self.retarget_in == 0 {
            self.retarget(ctx.bounds);
        }
        self.retarget_in -= 1;
        let action = if self.rng.chance(self.random_frac) {
            self.rng.below(crate::coordinator::N_ACTIONS)
        } else {
            // Step toward the way-point (cc and p move together in the
            // paper's action set; follow the dominant axis).
            let d = (self.target.0 as i64 - ctx.cc as i64) + (self.target.1 as i64 - ctx.p as i64);
            match d {
                d if d >= 3 => 3,
                1..=2 => 1,
                0 => 0,
                -2..=-1 => 2,
                _ => 4,
            }
        };
        let (cc, p) = ctx.bounds.apply(ctx.cc, ctx.p, action);
        Decision { cc, p, action: Some(action) }
    }
}

/// Run `runs` exploratory transfers of `mis` monitoring intervals each over
/// a mix of background regimes and return the pooled transitions.
pub fn collect_transitions(
    testbed: &Testbed,
    runs: usize,
    mis: usize,
    seed: u64,
) -> Vec<Transition> {
    let mut rng = Rng::new(seed);
    let regimes = ["low", "medium", "high"];
    let mut all = Vec::new();
    for run in 0..runs {
        let bg = Background::regime(regimes[run % regimes.len()], testbed.capacity_gbps);
        let builder = Controller::builder(testbed.clone()).background(bg);
        all.extend(explore_run(builder, mis, &mut rng));
    }
    all
}

/// Like [`collect_transitions`], but over a registered scenario's topology
/// and cross traffic (the scenario fixes the conditions; only seeds vary
/// across runs).
pub fn collect_transitions_scenario(
    scenario: &Scenario,
    runs: usize,
    mis: usize,
    seed: u64,
) -> Vec<Transition> {
    let mut rng = Rng::new(seed);
    let mut all = Vec::new();
    for _ in 0..runs {
        all.extend(explore_run(scenario.controller(), mis, &mut rng));
    }
    all
}

/// One exploration transfer on a preconfigured controller builder.
fn explore_run(
    builder: crate::coordinator::ControllerBuilder,
    mis: usize,
    rng: &mut Rng,
) -> Vec<Transition> {
    let mut ctl = builder
        .max_mis(mis)
        // Large enough to never complete within `mis` intervals.
        .job(TransferJob::files(10_000, 1 << 30))
        .reward(RewardKind::FairnessEfficiency)
        .engine(EngineProfile::efficient())
        .seed(rng.next_u64())
        .build();
    let report = ctl.run(Box::new(ExplorePolicy::new(rng.next_u64())), 0);
    transitions_from_records(&report.lane().records)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn collects_labeled_transitions_across_the_grid() {
        let tb = Testbed::chameleon();
        let ts = collect_transitions(&tb, 2, 60, 42);
        assert!(ts.len() >= 100, "got {}", ts.len());
        // All five actions appear.
        let mut seen = [false; 5];
        for t in &ts {
            seen[t.action] = true;
        }
        assert!(seen.iter().all(|&s| s), "actions seen: {seen:?}");
        // A reasonable spread of (cc, p) values.
        let distinct: std::collections::BTreeSet<(u32, u32)> =
            ts.iter().map(|t| (t.cc, t.p)).collect();
        assert!(distinct.len() > 10, "only {} distinct settings", distinct.len());
    }

    #[test]
    fn scenario_collection_yields_labeled_transitions() {
        let sc = Scenario::by_name("calm").unwrap();
        let ts = collect_transitions_scenario(&sc, 1, 60, 5);
        assert!(ts.len() >= 50, "got {}", ts.len());
        let distinct: std::collections::BTreeSet<(u32, u32)> =
            ts.iter().map(|t| (t.cc, t.p)).collect();
        assert!(distinct.len() > 5, "only {} distinct settings", distinct.len());
    }

    #[test]
    fn explore_policy_respects_bounds() {
        let tb = Testbed::chameleon();
        let ts = collect_transitions(&tb, 1, 80, 7);
        let b = ParamBounds::default();
        for t in &ts {
            assert!(t.cc >= b.cc_min && t.cc <= b.cc_max);
            assert!(t.p >= b.p_min && t.p <= b.p_max);
        }
    }
}
