//! Offline training loop over an [`Env`] (emulated or live).

use super::meters::ResourceMeter;
use crate::agents::DrlAgent;
use crate::emulator::Env;
use crate::util::stats;

/// Training budget and convergence detection.
#[derive(Debug, Clone)]
pub struct TrainConfig {
    /// Hard cap on environment steps.
    pub max_env_steps: usize,
    /// Episode length is owned by the Env; this caps episode count.
    pub max_episodes: usize,
    /// Convergence: moving-average (over `conv_window` episodes) episode
    /// reward improves by less than `conv_eps` (relative) for
    /// `conv_patience` consecutive episodes.
    pub conv_window: usize,
    pub conv_eps: f64,
    pub conv_patience: usize,
}

impl Default for TrainConfig {
    fn default() -> Self {
        TrainConfig {
            max_env_steps: 60_000,
            max_episodes: 10_000,
            conv_window: 20,
            conv_eps: 0.02,
            conv_patience: 30,
        }
    }
}

/// Everything Table 1 needs about one training run.
#[derive(Debug, Clone)]
pub struct TrainStats {
    pub algo: String,
    pub wall_s: f64,
    pub env_steps: usize,
    pub episodes: usize,
    pub train_calls: u64,
    /// Environment step at which the convergence criterion first held
    /// (= env_steps if it never converged within budget).
    pub steps_to_converge: usize,
    pub cpu_pct: f64,
    /// XLA share of wall time, percent (the Table-1 "GPU%" analogue).
    pub xla_pct: f64,
    pub mem_pct: f64,
    pub energy_kj: f64,
    /// Mean episode reward over time (one entry per episode).
    pub reward_curve: Vec<f64>,
}

/// Train `agent` in `env` until convergence or budget exhaustion.
pub fn train_offline(
    agent: &mut Box<dyn DrlAgent>,
    env: &mut dyn Env,
    cfg: &TrainConfig,
) -> TrainStats {
    let meter = ResourceMeter::start();
    let xla_before = agent.xla_seconds();
    let mut reward_curve = Vec::new();
    let mut env_steps = 0usize;
    let mut episodes = 0usize;
    let mut converged_at: Option<usize> = None;
    let mut best_ma = f64::MIN;
    let mut stall = 0usize;

    while env_steps < cfg.max_env_steps && episodes < cfg.max_episodes {
        let mut state = env.reset();
        let mut ep_reward = 0.0;
        loop {
            let action = agent.act(&state, true);
            let out = env.step(action);
            agent.observe(&state, action, out.reward, &out.state, out.done);
            ep_reward += out.reward;
            env_steps += 1;
            state = out.state;
            if out.done || env_steps >= cfg.max_env_steps {
                break;
            }
        }
        episodes += 1;
        reward_curve.push(ep_reward);

        // Convergence detection on the moving average.
        if converged_at.is_none() && reward_curve.len() >= cfg.conv_window {
            let ma = stats::mean(&reward_curve[reward_curve.len() - cfg.conv_window..]);
            if ma > best_ma * (1.0 + cfg.conv_eps) || best_ma == f64::MIN {
                best_ma = best_ma.max(ma);
                stall = 0;
            } else {
                stall += 1;
                if stall >= cfg.conv_patience {
                    converged_at = Some(env_steps);
                }
            }
        }
    }

    let r = meter.stop();
    let xla_s = agent.xla_seconds() - xla_before;
    TrainStats {
        algo: agent.name().to_string(),
        wall_s: r.wall_s,
        env_steps,
        episodes,
        train_calls: agent.train_steps(),
        steps_to_converge: converged_at.unwrap_or(env_steps),
        cpu_pct: r.cpu_pct,
        xla_pct: 100.0 * xla_s / r.wall_s.max(1e-9),
        mem_pct: r.mem_pct,
        energy_kj: r.energy_kj,
        reward_curve,
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::emulator::StepOut;
    use crate::util::Rng;

    /// A trivial 1-feature bandit env: action 1 good, others bad.
    struct Bandit {
        rng: Rng,
        steps: usize,
    }

    impl Env for Bandit {
        fn reset(&mut self) -> Vec<f32> {
            self.steps = 0;
            vec![0.0; 4]
        }

        fn step(&mut self, action: usize) -> StepOut {
            self.steps += 1;
            let reward = if action == 1 { 1.0 } else { -0.2 } + self.rng.normal_mean_sd(0.0, 0.05);
            StepOut {
                state: vec![self.rng.f32(); 4],
                reward,
                done: self.steps >= 10,
                throughput_gbps: 0.0,
                energy_j: 0.0,
            }
        }

        fn state_len(&self) -> usize {
            4
        }
    }

    /// An agent that learns nothing but acts — validates the driver loop.
    struct Fixed {
        xla: f64,
        observed: usize,
    }

    impl DrlAgent for Fixed {
        fn name(&self) -> &str {
            "fixed"
        }
        fn act(&mut self, _s: &[f32], _e: bool) -> usize {
            1
        }
        fn observe(&mut self, _s: &[f32], _a: usize, _r: f64, _n: &[f32], _d: bool) {
            self.observed += 1;
        }
        fn params(&self) -> &[f32] {
            &[]
        }
        fn set_params(&mut self, _p: Vec<f32>) {}
        fn train_steps(&self) -> u64 {
            0
        }
        fn xla_seconds(&self) -> f64 {
            self.xla
        }
    }

    #[test]
    fn driver_runs_episodes_and_converges() {
        let mut env = Bandit { rng: Rng::new(1), steps: 0 };
        let mut agent: Box<dyn DrlAgent> = Box::new(Fixed { xla: 0.0, observed: 0 });
        let cfg = TrainConfig {
            max_env_steps: 2000,
            conv_window: 5,
            conv_patience: 10,
            ..TrainConfig::default()
        };
        let stats = train_offline(&mut agent, &mut env, &cfg);
        assert!(stats.episodes > 10);
        assert_eq!(stats.env_steps, stats.episodes * 10);
        // A constant policy converges immediately (stable moving average).
        assert!(stats.steps_to_converge < stats.env_steps);
        assert!(!stats.reward_curve.is_empty());
        // Episode reward of always-optimal policy ~ 10.
        let tail = stats::mean(&stats.reward_curve[stats.reward_curve.len() - 5..]);
        assert!(tail > 8.0, "tail={tail}");
    }
}
