//! Seeded arrival-process workloads: transfers that come and go.
//!
//! A [`Scenario`] fixes the *network* conditions; an [`ArrivalSchedule`]
//! fixes the *workload* on top of it — when transfer applications join the
//! shared bottleneck, how much they move, and whether they are forced to
//! depart before finishing. Presets are either Poisson processes (seeded
//! exponential inter-arrivals) or explicit traces; every schedule is fully
//! determined by `(name, seed)`, so fleet reports stay bit-identical at any
//! `--jobs` count.
//!
//! Select one with `sparta fleet --scenario <name>` (`churn-light`,
//! `churn-heavy`, `flash-crowd`), or programmatically:
//!
//! ```
//! use sparta::scenarios::ArrivalSchedule;
//!
//! let sched = ArrivalSchedule::by_name("churn-heavy").unwrap();
//! let a = sched.arrivals(42);
//! let b = sched.arrivals(42);
//! assert_eq!(a, b); // same (schedule, seed) => same workload
//! assert!(!a.is_empty());
//! ```

use super::Scenario;
use crate::util::rng::mix_seed;
use crate::util::Rng;

/// One transfer application joining the session.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct ArrivalSpec {
    /// Monitoring interval at which the lane is admitted.
    pub at_mi: usize,
    /// Workload: `files` × `file_bytes`.
    pub files: usize,
    pub file_bytes: u64,
    /// Forced departure (cancel) this many MIs after admission, if the
    /// transfer has not completed by then — models users walking away.
    pub max_lifetime_mis: Option<usize>,
}

/// One wall-clock-indexed arrival: admitted at the MI boundary covering
/// `at_s` *simulated seconds*, whatever the MI length is. This is how a
/// long-running service (`sparta serve`) expresses "a user shows up at
/// 09:00:45" independently of its pacing.
#[derive(Debug, Clone, PartialEq)]
pub struct TimedArrival {
    /// Arrival time, simulated seconds since the run started.
    pub at_s: f64,
    pub files: usize,
    pub file_bytes: u64,
    pub max_lifetime_mis: Option<usize>,
}

/// How arrivals are generated.
#[derive(Debug, Clone)]
enum Process {
    /// Seeded Poisson process: exponential inter-arrival gaps, in MIs.
    Poisson {
        mean_gap_mis: f64,
        max_agents: usize,
        /// Inclusive range of per-arrival file counts.
        files: (usize, usize),
        file_bytes: u64,
        max_lifetime_mis: Option<usize>,
    },
    /// Open-loop (rate-based) Poisson process: exponential gaps drawn in
    /// *seconds* at a fixed offered rate, independent of MI length. The
    /// same schedule offers the same load per wall-clock second whether
    /// the service paces 0.5-second or 2-second MIs.
    OpenLoop {
        rate_per_s: f64,
        max_agents: usize,
        /// Inclusive range of per-arrival file counts.
        files: (usize, usize),
        file_bytes: u64,
        max_lifetime_mis: Option<usize>,
    },
    /// Explicit trace (already sorted by `at_mi`).
    Trace(Vec<ArrivalSpec>),
    /// Explicit wall-clock trace (already sorted by `at_s`).
    TimedTrace(Vec<TimedArrival>),
}

/// A named, reproducible dynamic workload over a registered [`Scenario`].
#[derive(Debug, Clone)]
pub struct ArrivalSchedule {
    /// Registry name (`sparta fleet --scenario <name>`).
    pub name: &'static str,
    /// One-line description for `sparta scenarios`.
    pub summary: &'static str,
    /// The shared-bottleneck network conditions the fleet runs under.
    pub scenario: Scenario,
    /// Fleet run length, MIs.
    pub horizon_mis: usize,
    process: Process,
}

impl ArrivalSchedule {
    /// The registered churn presets.
    pub fn all() -> Vec<ArrivalSchedule> {
        vec![
            ArrivalSchedule::churn_light(),
            ArrivalSchedule::churn_heavy(),
            ArrivalSchedule::flash_crowd(),
            ArrivalSchedule::open_loop(),
            ArrivalSchedule::timed_burst(),
        ]
    }

    pub fn by_name(name: &str) -> Option<ArrivalSchedule> {
        ArrivalSchedule::all().into_iter().find(|s| s.name == name)
    }

    /// Registry names, in registry order.
    pub fn names() -> Vec<&'static str> {
        ArrivalSchedule::all().iter().map(|s| s.name).collect()
    }

    /// Materialize the arrival list for one trial at 1-second MIs.
    /// Deterministic: the same `(schedule, seed)` yields the same
    /// workload; traces ignore the seed. See
    /// [`ArrivalSchedule::arrivals_scaled`] for other MI lengths.
    pub fn arrivals(&self, seed: u64) -> Vec<ArrivalSpec> {
        self.arrivals_scaled(seed, 1.0)
    }

    /// Materialize the arrival list for a run pacing `mi_s`-second MIs.
    /// Wall-clock-indexed processes (open-loop rates, timed traces) land
    /// at `at_mi = floor(at_s / mi_s)` — the workload tracks simulated
    /// *time*, so halving the MI length doubles the arrival's MI index
    /// but keeps its wall-clock instant. MI-indexed processes (Poisson
    /// gaps in MIs, MI traces) ignore `mi_s` by construction.
    pub fn arrivals_scaled(&self, seed: u64, mi_s: f64) -> Vec<ArrivalSpec> {
        match &self.process {
            Process::Trace(t) => t.clone(),
            Process::TimedTrace(t) => {
                let mut out = Vec::new();
                for a in t {
                    let at_mi = (a.at_s / mi_s).floor() as usize;
                    if at_mi >= self.horizon_mis {
                        continue;
                    }
                    out.push(ArrivalSpec {
                        at_mi,
                        files: a.files,
                        file_bytes: a.file_bytes,
                        max_lifetime_mis: a.max_lifetime_mis,
                    });
                }
                out
            }
            Process::Poisson { mean_gap_mis, max_agents, files, file_bytes, max_lifetime_mis } => {
                // The schedule name joins the mix so two schedules under
                // the same trial seed draw different processes.
                let mut rng = Rng::new(mix_seed(seed, self.name, 0));
                let mut out = Vec::new();
                // One lane from the start so the bottleneck is never empty.
                out.push(ArrivalSpec {
                    at_mi: 0,
                    files: files.0 + rng.below(files.1 - files.0 + 1),
                    file_bytes: *file_bytes,
                    max_lifetime_mis: *max_lifetime_mis,
                });
                let mut at = 0.0f64;
                while out.len() < *max_agents {
                    // Exponential inter-arrival gap.
                    at += -mean_gap_mis * (1.0 - rng.f64()).ln();
                    let at_mi = at.floor() as usize;
                    if at_mi >= self.horizon_mis {
                        break;
                    }
                    out.push(ArrivalSpec {
                        at_mi,
                        files: files.0 + rng.below(files.1 - files.0 + 1),
                        file_bytes: *file_bytes,
                        max_lifetime_mis: *max_lifetime_mis,
                    });
                }
                out
            }
            Process::OpenLoop { rate_per_s, max_agents, files, file_bytes, max_lifetime_mis } => {
                let mut rng = Rng::new(mix_seed(seed, self.name, 0));
                let mut out = Vec::new();
                // One lane from the start, mirroring the Poisson presets.
                out.push(ArrivalSpec {
                    at_mi: 0,
                    files: files.0 + rng.below(files.1 - files.0 + 1),
                    file_bytes: *file_bytes,
                    max_lifetime_mis: *max_lifetime_mis,
                });
                let mut at_s = 0.0f64;
                while out.len() < *max_agents {
                    // Exponential inter-arrival gap, in seconds.
                    at_s += -(1.0 - rng.f64()).ln() / rate_per_s;
                    let at_mi = (at_s / mi_s).floor() as usize;
                    if at_mi >= self.horizon_mis {
                        break;
                    }
                    out.push(ArrivalSpec {
                        at_mi,
                        files: files.0 + rng.below(files.1 - files.0 + 1),
                        file_bytes: *file_bytes,
                        max_lifetime_mis: *max_lifetime_mis,
                    });
                }
                out
            }
        }
    }

    /// Light churn: a handful of medium transfers trickling onto the shared
    /// Chameleon WAN, all running to completion.
    pub fn churn_light() -> ArrivalSchedule {
        ArrivalSchedule {
            name: "churn-light",
            summary: "poisson arrivals (~1 per 30 MIs, max 8), no forced departures",
            scenario: Scenario::by_name("chameleon").expect("chameleon preset registered"),
            horizon_mis: 360,
            process: Process::Poisson {
                mean_gap_mis: 30.0,
                max_agents: 8,
                files: (8, 16),
                file_bytes: 128 << 20,
                max_lifetime_mis: None,
            },
        }
    }

    /// Heavy churn: arrivals offer more load than the bottleneck can carry
    /// (mean ~6 GB per ~6 MIs against a ~0.8 GB/s share), so lanes queue up
    /// and the 40-MI lifetime yanks many before finishing — the regime the
    /// batch API could not express.
    pub fn churn_heavy() -> ArrivalSchedule {
        ArrivalSchedule {
            name: "churn-heavy",
            summary: "overloaded poisson arrivals (~1 per 6 MIs, max 30), forced departure after 40 MIs",
            scenario: Scenario::by_name("chameleon").expect("chameleon preset registered"),
            horizon_mis: 360,
            process: Process::Poisson {
                mean_gap_mis: 6.0,
                max_agents: 30,
                files: (8, 40),
                file_bytes: 256 << 20,
                max_lifetime_mis: Some(40),
            },
        }
    }

    /// The churn-heavy process at an arbitrary fleet size: same per-lane
    /// workload and 40-MI forced departure as [`ArrivalSchedule::churn_heavy`],
    /// with `max_agents = lanes` and the Poisson gap shrunk (never widened
    /// past the preset's 6 MIs) so the whole fleet lands inside ~70 % of
    /// `horizon_mis`. This is the `sparta bench` scale curve
    /// (16/64/256 lanes) and the golden-replay workload (128 lanes);
    /// arrivals stay fully determined by `(lanes, horizon, seed)`.
    pub fn churn_heavy_scaled(lanes: usize, horizon_mis: usize) -> ArrivalSchedule {
        let mut s = ArrivalSchedule::churn_heavy();
        s.horizon_mis = horizon_mis;
        let gap = (horizon_mis as f64 * 0.7 / lanes.max(1) as f64).min(6.0);
        if let Process::Poisson { mean_gap_mis, max_agents, .. } = &mut s.process {
            *max_agents = lanes;
            *mean_gap_mis = gap;
        }
        s
    }

    /// Flash crowd: one long-running marathon transfer (~75 GB, spanning
    /// the burst), then eight short-lived peers slamming the same
    /// bottleneck at MI 40, and a straggler near the end — trace-driven,
    /// identical for every seed.
    pub fn flash_crowd() -> ArrivalSchedule {
        let mut trace = vec![ArrivalSpec {
            at_mi: 0,
            files: 600,
            file_bytes: 128 << 20,
            max_lifetime_mis: None,
        }];
        for k in 0..8 {
            trace.push(ArrivalSpec {
                at_mi: 40 + 2 * k,
                files: 6,
                file_bytes: 128 << 20,
                max_lifetime_mis: Some(80),
            });
        }
        trace.push(ArrivalSpec {
            at_mi: 200,
            files: 8,
            file_bytes: 128 << 20,
            max_lifetime_mis: None,
        });
        ArrivalSchedule {
            name: "flash-crowd",
            summary: "trace: 1 marathon + 8-peer burst at MI 40 + straggler at MI 200 (calm WAN)",
            scenario: Scenario::by_name("calm").expect("calm preset registered"),
            horizon_mis: 360,
            process: Process::Trace(trace),
        }
    }

    /// Open-loop churn: users arrive at a fixed offered rate (~1 per
    /// 5.6 wall-clock seconds) regardless of how fast the service is
    /// draining — the rate-based regime a long-running `sparta serve`
    /// daemon faces, where slowing down does not slow the arrivals.
    /// Lifetimes are still counted in MIs (a lane's forced departure is
    /// a control decision, not a wall-clock event).
    pub fn open_loop() -> ArrivalSchedule {
        ArrivalSchedule {
            name: "open-loop",
            summary: "open-loop poisson (~0.18 arrivals/s, max 30), forced departure after 60 MIs",
            scenario: Scenario::by_name("chameleon").expect("chameleon preset registered"),
            horizon_mis: 360,
            process: Process::OpenLoop {
                rate_per_s: 0.18,
                max_agents: 30,
                files: (8, 24),
                file_bytes: 128 << 20,
                max_lifetime_mis: Some(60),
            },
        }
    }

    /// Wall-clock burst trace: a marathon at t=0, a three-user pile-up
    /// in the 45–48 s window, and two latecomers — all pinned to
    /// simulated seconds, so the same burst lands mid-run whether the
    /// service paces sub-second or multi-second MIs.
    pub fn timed_burst() -> ArrivalSchedule {
        let mut trace = vec![TimedArrival {
            at_s: 0.0,
            files: 200,
            file_bytes: 128 << 20,
            max_lifetime_mis: None,
        }];
        for k in 0..3 {
            trace.push(TimedArrival {
                at_s: 45.5 + 1.25 * k as f64,
                files: 6,
                file_bytes: 128 << 20,
                max_lifetime_mis: Some(80),
            });
        }
        trace.push(TimedArrival {
            at_s: 120.75,
            files: 10,
            file_bytes: 128 << 20,
            max_lifetime_mis: None,
        });
        trace.push(TimedArrival {
            at_s: 240.0,
            files: 8,
            file_bytes: 128 << 20,
            max_lifetime_mis: Some(60),
        });
        ArrivalSchedule {
            name: "timed-burst",
            summary: "wall-clock trace: marathon + 3-user pile-up at ~45 s + latecomers (calm WAN)",
            scenario: Scenario::by_name("calm").expect("calm preset registered"),
            horizon_mis: 360,
            process: Process::TimedTrace(trace),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn registry_resolves_and_names_are_unique() {
        let names = ArrivalSchedule::names();
        for want in ["churn-light", "churn-heavy", "flash-crowd", "open-loop", "timed-burst"] {
            assert!(names.contains(&want), "missing schedule '{want}'");
        }
        let mut dedup = names.clone();
        dedup.sort_unstable();
        dedup.dedup();
        assert_eq!(dedup.len(), names.len(), "duplicate schedule names");
        assert!(ArrivalSchedule::by_name("no-such-schedule").is_none());
    }

    #[test]
    fn poisson_schedules_are_seed_deterministic_and_sorted() {
        for sched in ArrivalSchedule::all() {
            let a = sched.arrivals(7);
            let b = sched.arrivals(7);
            assert_eq!(a, b, "{}: same seed must reproduce", sched.name);
            assert!(!a.is_empty(), "{}: empty workload", sched.name);
            assert!(
                a.windows(2).all(|w| w[0].at_mi <= w[1].at_mi),
                "{}: arrivals out of order",
                sched.name
            );
            assert!(
                a.iter().all(|x| x.at_mi < sched.horizon_mis),
                "{}: arrival past horizon",
                sched.name
            );
            assert_eq!(a[0].at_mi, 0, "{}: no lane at MI 0", sched.name);
        }
    }

    #[test]
    fn poisson_seeds_diverge_traces_do_not() {
        let heavy = ArrivalSchedule::by_name("churn-heavy").unwrap();
        assert_ne!(heavy.arrivals(1), heavy.arrivals(2));
        let crowd = ArrivalSchedule::by_name("flash-crowd").unwrap();
        assert_eq!(crowd.arrivals(1), crowd.arrivals(2));
    }

    #[test]
    fn scaled_churn_heavy_reaches_the_requested_fleet_size() {
        for lanes in [16usize, 64, 256] {
            let s = ArrivalSchedule::churn_heavy_scaled(lanes, 120);
            let a = s.arrivals(42);
            assert!(a.len() * 10 >= lanes * 8, "{lanes} lanes: only {} arrivals", a.len());
            assert!(a.len() <= lanes, "{lanes} lanes: {} arrivals", a.len());
            assert_eq!(s.arrivals(42), a, "{lanes} lanes: not seed-deterministic");
        }
    }

    #[test]
    fn open_loop_holds_its_wall_clock_rate_across_mi_lengths() {
        let ol = ArrivalSchedule::by_name("open-loop").unwrap();
        let fine = ol.arrivals_scaled(7, 0.5);
        let coarse = ol.arrivals_scaled(7, 2.0);
        assert_eq!(ol.arrivals_scaled(7, 0.5), fine, "not deterministic");
        // Coarser MIs cover more wall clock inside the same MI horizon,
        // so the coarse expansion can only extend the fine one; the
        // shared prefix is the same wall-clock process, so MI indices
        // relate by exact floor division and workloads match.
        assert!(!fine.is_empty() && fine.len() <= coarse.len());
        for (f, c) in fine.iter().zip(coarse.iter()) {
            assert_eq!(c.at_mi, f.at_mi / 4, "mismatched wall-clock instant");
            assert_eq!(c.files, f.files);
        }
        // And the rate is really per second: ~0.18/s over a 720 s coarse
        // horizon easily saturates the 30-agent cap.
        assert_eq!(coarse.len(), 30);
    }

    #[test]
    fn timed_trace_lands_on_wall_clock_boundaries() {
        let tb = ArrivalSchedule::by_name("timed-burst").unwrap();
        let unit = tb.arrivals(1);
        assert_eq!(unit, tb.arrivals(2), "traces must ignore the seed");
        assert_eq!(unit[0].at_mi, 0);
        let burst: Vec<usize> = unit[1..4].iter().map(|a| a.at_mi).collect();
        assert_eq!(burst, vec![45, 46, 48], "pile-up MIs at 1 s per MI");
        let half = tb.arrivals_scaled(1, 0.5);
        let burst: Vec<usize> = half[1..4].iter().map(|a| a.at_mi).collect();
        assert_eq!(burst, vec![91, 93, 96], "pile-up MIs at 0.5 s per MI");
        // At 0.5 s per MI the 360-MI horizon covers only 180 s, so the
        // 240 s latecomer falls off the end.
        assert_eq!(half.len() + 1, unit.len(), "horizon must truncate in wall clock");
    }

    #[test]
    fn churn_heavy_actually_churns() {
        let heavy = ArrivalSchedule::by_name("churn-heavy").unwrap();
        let arrivals = heavy.arrivals(42);
        assert!(arrivals.len() >= 6, "only {} arrivals", arrivals.len());
        assert!(arrivals.iter().all(|a| a.max_lifetime_mis == Some(40)));
    }
}
