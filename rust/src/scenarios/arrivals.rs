//! Seeded arrival-process workloads: transfers that come and go.
//!
//! A [`Scenario`] fixes the *network* conditions; an [`ArrivalSchedule`]
//! fixes the *workload* on top of it — when transfer applications join the
//! shared bottleneck, how much they move, and whether they are forced to
//! depart before finishing. Presets are either Poisson processes (seeded
//! exponential inter-arrivals) or explicit traces; every schedule is fully
//! determined by `(name, seed)`, so fleet reports stay bit-identical at any
//! `--jobs` count.
//!
//! Select one with `sparta fleet --scenario <name>` (`churn-light`,
//! `churn-heavy`, `flash-crowd`), or programmatically:
//!
//! ```
//! use sparta::scenarios::ArrivalSchedule;
//!
//! let sched = ArrivalSchedule::by_name("churn-heavy").unwrap();
//! let a = sched.arrivals(42);
//! let b = sched.arrivals(42);
//! assert_eq!(a, b); // same (schedule, seed) => same workload
//! assert!(!a.is_empty());
//! ```

use super::Scenario;
use crate::util::rng::mix_seed;
use crate::util::Rng;

/// One transfer application joining the session.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct ArrivalSpec {
    /// Monitoring interval at which the lane is admitted.
    pub at_mi: usize,
    /// Workload: `files` × `file_bytes`.
    pub files: usize,
    pub file_bytes: u64,
    /// Forced departure (cancel) this many MIs after admission, if the
    /// transfer has not completed by then — models users walking away.
    pub max_lifetime_mis: Option<usize>,
}

/// How arrivals are generated.
#[derive(Debug, Clone)]
enum Process {
    /// Seeded Poisson process: exponential inter-arrival gaps.
    Poisson {
        mean_gap_mis: f64,
        max_agents: usize,
        /// Inclusive range of per-arrival file counts.
        files: (usize, usize),
        file_bytes: u64,
        max_lifetime_mis: Option<usize>,
    },
    /// Explicit trace (already sorted by `at_mi`).
    Trace(Vec<ArrivalSpec>),
}

/// A named, reproducible dynamic workload over a registered [`Scenario`].
#[derive(Debug, Clone)]
pub struct ArrivalSchedule {
    /// Registry name (`sparta fleet --scenario <name>`).
    pub name: &'static str,
    /// One-line description for `sparta scenarios`.
    pub summary: &'static str,
    /// The shared-bottleneck network conditions the fleet runs under.
    pub scenario: Scenario,
    /// Fleet run length, MIs.
    pub horizon_mis: usize,
    process: Process,
}

impl ArrivalSchedule {
    /// The registered churn presets.
    pub fn all() -> Vec<ArrivalSchedule> {
        vec![
            ArrivalSchedule::churn_light(),
            ArrivalSchedule::churn_heavy(),
            ArrivalSchedule::flash_crowd(),
        ]
    }

    pub fn by_name(name: &str) -> Option<ArrivalSchedule> {
        ArrivalSchedule::all().into_iter().find(|s| s.name == name)
    }

    /// Registry names, in registry order.
    pub fn names() -> Vec<&'static str> {
        ArrivalSchedule::all().iter().map(|s| s.name).collect()
    }

    /// Materialize the arrival list for one trial. Deterministic: the same
    /// `(schedule, seed)` yields the same workload; traces ignore the seed.
    pub fn arrivals(&self, seed: u64) -> Vec<ArrivalSpec> {
        match &self.process {
            Process::Trace(t) => t.clone(),
            Process::Poisson { mean_gap_mis, max_agents, files, file_bytes, max_lifetime_mis } => {
                // The schedule name joins the mix so two schedules under
                // the same trial seed draw different processes.
                let mut rng = Rng::new(mix_seed(seed, self.name, 0));
                let mut out = Vec::new();
                // One lane from the start so the bottleneck is never empty.
                out.push(ArrivalSpec {
                    at_mi: 0,
                    files: files.0 + rng.below(files.1 - files.0 + 1),
                    file_bytes: *file_bytes,
                    max_lifetime_mis: *max_lifetime_mis,
                });
                let mut at = 0.0f64;
                while out.len() < *max_agents {
                    // Exponential inter-arrival gap.
                    at += -mean_gap_mis * (1.0 - rng.f64()).ln();
                    let at_mi = at.floor() as usize;
                    if at_mi >= self.horizon_mis {
                        break;
                    }
                    out.push(ArrivalSpec {
                        at_mi,
                        files: files.0 + rng.below(files.1 - files.0 + 1),
                        file_bytes: *file_bytes,
                        max_lifetime_mis: *max_lifetime_mis,
                    });
                }
                out
            }
        }
    }

    /// Light churn: a handful of medium transfers trickling onto the shared
    /// Chameleon WAN, all running to completion.
    pub fn churn_light() -> ArrivalSchedule {
        ArrivalSchedule {
            name: "churn-light",
            summary: "poisson arrivals (~1 per 30 MIs, max 8), no forced departures",
            scenario: Scenario::by_name("chameleon").expect("chameleon preset registered"),
            horizon_mis: 360,
            process: Process::Poisson {
                mean_gap_mis: 30.0,
                max_agents: 8,
                files: (8, 16),
                file_bytes: 128 << 20,
                max_lifetime_mis: None,
            },
        }
    }

    /// Heavy churn: arrivals offer more load than the bottleneck can carry
    /// (mean ~6 GB per ~6 MIs against a ~0.8 GB/s share), so lanes queue up
    /// and the 40-MI lifetime yanks many before finishing — the regime the
    /// batch API could not express.
    pub fn churn_heavy() -> ArrivalSchedule {
        ArrivalSchedule {
            name: "churn-heavy",
            summary: "overloaded poisson arrivals (~1 per 6 MIs, max 30), forced departure after 40 MIs",
            scenario: Scenario::by_name("chameleon").expect("chameleon preset registered"),
            horizon_mis: 360,
            process: Process::Poisson {
                mean_gap_mis: 6.0,
                max_agents: 30,
                files: (8, 40),
                file_bytes: 256 << 20,
                max_lifetime_mis: Some(40),
            },
        }
    }

    /// The churn-heavy process at an arbitrary fleet size: same per-lane
    /// workload and 40-MI forced departure as [`ArrivalSchedule::churn_heavy`],
    /// with `max_agents = lanes` and the Poisson gap shrunk (never widened
    /// past the preset's 6 MIs) so the whole fleet lands inside ~70 % of
    /// `horizon_mis`. This is the `sparta bench` scale curve
    /// (16/64/256 lanes) and the golden-replay workload (128 lanes);
    /// arrivals stay fully determined by `(lanes, horizon, seed)`.
    pub fn churn_heavy_scaled(lanes: usize, horizon_mis: usize) -> ArrivalSchedule {
        let mut s = ArrivalSchedule::churn_heavy();
        s.horizon_mis = horizon_mis;
        let gap = (horizon_mis as f64 * 0.7 / lanes.max(1) as f64).min(6.0);
        if let Process::Poisson { mean_gap_mis, max_agents, .. } = &mut s.process {
            *max_agents = lanes;
            *mean_gap_mis = gap;
        }
        s
    }

    /// Flash crowd: one long-running marathon transfer (~75 GB, spanning
    /// the burst), then eight short-lived peers slamming the same
    /// bottleneck at MI 40, and a straggler near the end — trace-driven,
    /// identical for every seed.
    pub fn flash_crowd() -> ArrivalSchedule {
        let mut trace = vec![ArrivalSpec {
            at_mi: 0,
            files: 600,
            file_bytes: 128 << 20,
            max_lifetime_mis: None,
        }];
        for k in 0..8 {
            trace.push(ArrivalSpec {
                at_mi: 40 + 2 * k,
                files: 6,
                file_bytes: 128 << 20,
                max_lifetime_mis: Some(80),
            });
        }
        trace.push(ArrivalSpec {
            at_mi: 200,
            files: 8,
            file_bytes: 128 << 20,
            max_lifetime_mis: None,
        });
        ArrivalSchedule {
            name: "flash-crowd",
            summary: "trace: 1 marathon + 8-peer burst at MI 40 + straggler at MI 200 (calm WAN)",
            scenario: Scenario::by_name("calm").expect("calm preset registered"),
            horizon_mis: 360,
            process: Process::Trace(trace),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn registry_resolves_and_names_are_unique() {
        let names = ArrivalSchedule::names();
        for want in ["churn-light", "churn-heavy", "flash-crowd"] {
            assert!(names.contains(&want), "missing schedule '{want}'");
        }
        let mut dedup = names.clone();
        dedup.sort_unstable();
        dedup.dedup();
        assert_eq!(dedup.len(), names.len(), "duplicate schedule names");
        assert!(ArrivalSchedule::by_name("no-such-schedule").is_none());
    }

    #[test]
    fn poisson_schedules_are_seed_deterministic_and_sorted() {
        for sched in ArrivalSchedule::all() {
            let a = sched.arrivals(7);
            let b = sched.arrivals(7);
            assert_eq!(a, b, "{}: same seed must reproduce", sched.name);
            assert!(!a.is_empty(), "{}: empty workload", sched.name);
            assert!(
                a.windows(2).all(|w| w[0].at_mi <= w[1].at_mi),
                "{}: arrivals out of order",
                sched.name
            );
            assert!(
                a.iter().all(|x| x.at_mi < sched.horizon_mis),
                "{}: arrival past horizon",
                sched.name
            );
            assert_eq!(a[0].at_mi, 0, "{}: no lane at MI 0", sched.name);
        }
    }

    #[test]
    fn poisson_seeds_diverge_traces_do_not() {
        let heavy = ArrivalSchedule::by_name("churn-heavy").unwrap();
        assert_ne!(heavy.arrivals(1), heavy.arrivals(2));
        let crowd = ArrivalSchedule::by_name("flash-crowd").unwrap();
        assert_eq!(crowd.arrivals(1), crowd.arrivals(2));
    }

    #[test]
    fn scaled_churn_heavy_reaches_the_requested_fleet_size() {
        for lanes in [16usize, 64, 256] {
            let s = ArrivalSchedule::churn_heavy_scaled(lanes, 120);
            let a = s.arrivals(42);
            assert!(a.len() * 10 >= lanes * 8, "{lanes} lanes: only {} arrivals", a.len());
            assert!(a.len() <= lanes, "{lanes} lanes: {} arrivals", a.len());
            assert_eq!(s.arrivals(42), a, "{lanes} lanes: not seed-deterministic");
        }
    }

    #[test]
    fn churn_heavy_actually_churns() {
        let heavy = ArrivalSchedule::by_name("churn-heavy").unwrap();
        let arrivals = heavy.arrivals(42);
        assert!(arrivals.len() >= 6, "only {} arrivals", arrivals.len());
        assert!(arrivals.iter().all(|a| a.max_lifetime_mis == Some(40)));
    }
}
