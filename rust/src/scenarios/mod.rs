//! Named, seeded evaluation scenarios.
//!
//! The paper evaluates on two-ish testbed links with one background pattern
//! each; the registry below diversifies that into a library of reproducible
//! network conditions — different bottleneck locations (sender NIC, shared
//! WAN, receiver I/O), buffer depths and cross-traffic processes — all
//! expressed as [`Topology`]s over the paper's testbed presets and consumed
//! through the [`Substrate`] trait. Every scenario is fully determined by
//! `(name, seed)`: the same pair reproduces the same run bit-for-bit.
//!
//! Select one from the CLI with `--scenario <name>` (`sparta scenarios`
//! lists the registry), or programmatically:
//!
//! ```
//! use sparta::scenarios::Scenario;
//!
//! let sc = Scenario::by_name("receiver-limited").unwrap();
//! let mut sub = sc.substrate(42);
//! let id = sub.add_flow(4, 4, None);
//! let metrics = sub.run_mi(1.0);
//! assert!(metrics[id.0].rtt_s > 0.0);
//! ```

pub mod arrivals;

pub use arrivals::{ArrivalSchedule, ArrivalSpec};

use crate::coordinator::{Controller, ControllerBuilder, Session, SessionBuilder};
use crate::net::background::Background;
use crate::net::{NetworkSim, Substrate, Testbed, Topology};

/// A named, reproducible evaluation condition: a testbed preset plus the
/// path topology (and cross traffic) to run it under.
#[derive(Debug, Clone)]
pub struct Scenario {
    /// Registry name (`sparta ... --scenario <name>`).
    pub name: &'static str,
    /// One-line description for `sparta scenarios`.
    pub summary: &'static str,
    pub testbed: Testbed,
    pub topology: Topology,
}

impl Scenario {
    /// Build the concrete simulator for this scenario. Deterministic:
    /// the same `(scenario, seed)` yields bit-identical runs.
    pub fn sim(&self, seed: u64) -> NetworkSim {
        NetworkSim::from_topology(self.testbed.clone(), &self.topology, seed)
    }

    /// Build the scenario's substrate behind the trait the control plane
    /// consumes.
    pub fn substrate(&self, seed: u64) -> Box<dyn Substrate> {
        Box::new(self.sim(seed))
    }

    /// A controller builder preconfigured for this scenario (call `.job()`,
    /// `.seed()` etc. and `.build()` as usual).
    pub fn controller(&self) -> ControllerBuilder {
        Controller::builder(self.testbed.clone()).topology(self.topology.clone())
    }

    /// A step-driven session builder preconfigured for this scenario —
    /// the entry point for dynamic-admission workloads (`sparta fleet`).
    /// Energy accounting defaults to the lumped compat rail; see
    /// [`Scenario::session_host_resolved`] for shared host ledgers.
    pub fn session(&self) -> SessionBuilder {
        Session::builder(self.testbed.clone()).topology(self.topology.clone())
    }

    /// Like [`Scenario::session`], but with host-resolved energy
    /// accounting: every lane colocated on the scenario's sender/receiver
    /// hosts (from the testbed preset) bills one shared [`HostLedger`]
    /// per host, so fixed power is paid once per host, not once per lane.
    ///
    /// [`HostLedger`]: crate::energy::HostLedger
    pub fn session_host_resolved(&self) -> SessionBuilder {
        self.session().energy(self.testbed.energy_hosts())
    }

    /// The scenario's end-host definitions (sender, receiver), from its
    /// testbed preset.
    pub fn hosts(&self) -> (crate::energy::HostSpec, crate::energy::HostSpec) {
        (self.testbed.sender_host(), self.testbed.receiver_host())
    }

    /// Look up a registered scenario by name.
    pub fn by_name(name: &str) -> Option<Scenario> {
        Scenario::all().into_iter().find(|s| s.name == name)
    }

    /// Registry names, in registry order.
    pub fn names() -> Vec<&'static str> {
        Scenario::all().iter().map(|s| s.name).collect()
    }

    /// The full registry: the three paper testbeds under their default
    /// conditions, plus the stress presets.
    pub fn all() -> Vec<Scenario> {
        let mut v = Scenario::defaults();
        v.extend([
            Scenario::calm(),
            Scenario::diurnal_bg(),
            Scenario::bursty_incast(),
            Scenario::lossy_wan(),
            Scenario::receiver_limited(),
            Scenario::nic_limited(),
            Scenario::contended_peers(),
        ]);
        v
    }

    /// The paper's three testbeds as scenarios (single WAN bottleneck,
    /// default background) — the default `sparta compare` matrix.
    pub fn defaults() -> Vec<Scenario> {
        Testbed::all()
            .into_iter()
            .map(|tb| Scenario {
                name: tb.name,
                summary: "paper testbed preset, default (medium) background",
                topology: Topology::single(&tb),
                testbed: tb,
            })
            .collect()
    }

    /// Near-idle shared WAN: the background never exceeds 5% of capacity,
    /// so the optimum (cc, p) is wherever the end systems saturate.
    pub fn calm() -> Scenario {
        let tb = Testbed::chameleon();
        let bg = Background::regime("low", tb.capacity_gbps);
        Scenario {
            name: "calm",
            summary: "chameleon, near-idle WAN (5% background)",
            topology: Topology::single(&tb).with_wan_background(bg),
            testbed: tb,
        }
    }

    /// Strong time-of-day swing: the background moves between ~10% and ~60%
    /// of capacity over a 5-minute period, so the optimum keeps shifting.
    pub fn diurnal_bg() -> Scenario {
        let tb = Testbed::chameleon();
        let cap = tb.capacity_gbps;
        let bg = Background::Diurnal {
            mean_gbps: 0.35 * cap,
            amplitude_gbps: 0.25 * cap,
            period_s: 300.0,
            jitter_gbps: 0.03 * cap,
        };
        Scenario {
            name: "diurnal-bg",
            summary: "chameleon, strong 5-minute diurnal background swing",
            topology: Topology::single(&tb).with_wan_background(bg),
            testbed: tb,
        }
    }

    /// Incast-like on/off bursts on a shallow-buffered link: the background
    /// jumps between 5% and 70% of capacity with ~0.15/s switching.
    pub fn bursty_incast() -> Scenario {
        let mut tb = Testbed::cloudlab();
        tb.buffer_bdp = 0.5; // shallow buffer: bursts overflow it quickly
        let cap = tb.capacity_gbps;
        let bg = Background::Bursty {
            low_gbps: 0.05 * cap,
            high_gbps: 0.70 * cap,
            switch_prob: 0.15,
        };
        Scenario {
            name: "bursty-incast",
            summary: "cloudlab, shallow buffer, on/off incast bursts to 70%",
            topology: Topology::single(&tb).with_wan_background(bg),
            testbed: tb,
        }
    }

    /// Persistently lossy wide area: a quarter-BDP buffer under heavy
    /// background keeps the path at a visible standing loss rate.
    pub fn lossy_wan() -> Scenario {
        let mut tb = Testbed::fabric();
        tb.buffer_bdp = 0.25;
        let bg = Background::regime("high", tb.capacity_gbps);
        Scenario {
            name: "lossy-wan",
            summary: "fabric, quarter-BDP buffer under heavy background",
            topology: Topology::single(&tb).with_wan_background(bg),
            testbed: tb,
        }
    }

    /// The receiver's storage/ingest stage (8 Gbps) is the bottleneck, not
    /// the 25 Gbps WAN — ramping (cc, p) past the ingest rate only buys loss.
    pub fn receiver_limited() -> Scenario {
        let tb = Testbed::cloudlab();
        let bg = Background::regime("medium", tb.capacity_gbps);
        Scenario {
            name: "receiver-limited",
            summary: "cloudlab WAN behind an 8 Gbps receiver I/O stage",
            topology: Topology::three_stage(&tb, tb.capacity_gbps, 8.0)
                .with_wan_background(bg),
            testbed: tb,
        }
    }

    /// The sender's NIC/host egress (4 Gbps) is the bottleneck; the WAN is
    /// comfortable.
    pub fn nic_limited() -> Scenario {
        let tb = Testbed::chameleon();
        let bg = Background::regime("low", tb.capacity_gbps);
        Scenario {
            name: "nic-limited",
            summary: "chameleon WAN behind a 4 Gbps sender NIC stage",
            topology: Topology::three_stage(&tb, 4.0, tb.capacity_gbps)
                .with_wan_background(bg),
            testbed: tb,
        }
    }

    /// Peer transfers arriving and departing on the shared WAN: a
    /// piecewise-constant schedule steps the contention between ~10% and
    /// ~75% of capacity every one to two minutes.
    pub fn contended_peers() -> Scenario {
        let tb = Testbed::chameleon();
        let cap = tb.capacity_gbps;
        let schedule = vec![
            (0.0, 0.10 * cap),
            (60.0, 0.65 * cap),
            (150.0, 0.25 * cap),
            (240.0, 0.75 * cap),
            (330.0, 0.15 * cap),
            (420.0, 0.55 * cap),
            (540.0, 0.10 * cap),
        ];
        Scenario {
            name: "contended-peers",
            summary: "chameleon, peer transfers joining/leaving the WAN",
            topology: Topology::three_stage(&tb, cap, cap)
                .with_wan_background(Background::Steps { schedule }),
            testbed: tb,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn registry_has_stress_presets_and_defaults() {
        let names = Scenario::names();
        for want in [
            "chameleon",
            "cloudlab",
            "fabric",
            "calm",
            "diurnal-bg",
            "bursty-incast",
            "lossy-wan",
            "receiver-limited",
            "nic-limited",
            "contended-peers",
        ] {
            assert!(names.contains(&want), "missing scenario '{want}'");
        }
        // ≥ 6 presets beyond the paper's testbeds.
        assert!(names.len() - Scenario::defaults().len() >= 6);
    }

    #[test]
    fn names_are_unique_and_resolve() {
        let names = Scenario::names();
        let mut dedup = names.clone();
        dedup.sort_unstable();
        dedup.dedup();
        assert_eq!(dedup.len(), names.len(), "duplicate scenario names");
        for n in names {
            let sc = Scenario::by_name(n).expect(n);
            assert_eq!(sc.name, n);
        }
        assert!(Scenario::by_name("no-such-scenario").is_none());
    }

    /// Every registered scenario builds and runs 5 MIs deterministically:
    /// identical under the same seed, divergent across seeds.
    #[test]
    fn every_scenario_runs_deterministically() {
        for sc in Scenario::all() {
            let run = |seed: u64| {
                let mut sub = sc.substrate(seed);
                let id = sub.add_flow(4, 4, None);
                let mut out = Vec::new();
                for _ in 0..5 {
                    out.push(sub.run_mi(1.0)[id.0]);
                }
                out
            };
            let a = run(1);
            let b = run(1);
            assert_eq!(a, b, "{}: same seed must reproduce", sc.name);
            let c = run(2);
            assert_ne!(a, c, "{}: different seeds should diverge", sc.name);
            for m in &a {
                assert!(m.throughput_gbps >= 0.0 && m.rtt_s > 0.0, "{}", sc.name);
            }
        }
    }

    #[test]
    fn bottleneck_scenarios_have_three_stages() {
        for name in ["receiver-limited", "nic-limited", "contended-peers"] {
            let sc = Scenario::by_name(name).unwrap();
            assert_eq!(sc.topology.segments.len(), 3, "{name}");
        }
        assert_eq!(Scenario::by_name("calm").unwrap().topology.segments.len(), 1);
    }

    #[test]
    fn receiver_limited_caps_below_wan() {
        let sc = Scenario::by_name("receiver-limited").unwrap();
        assert!(sc.topology.min_capacity_gbps() < sc.testbed.capacity_gbps);
    }

    /// Scenario host definitions come from the testbed preset, and the
    /// host-resolved session builder actually switches accounting modes.
    #[test]
    fn scenario_hosts_resolve_from_testbed() {
        let sc = Scenario::by_name("calm").unwrap();
        let (tx, rx) = sc.hosts();
        assert_eq!(tx.name, "chameleon-tx");
        assert_eq!(rx.name, "chameleon-rx");
        let s = sc.session_host_resolved().seed(1).build();
        assert!(s.energy_host_resolved());
        let s = sc.session().seed(1).build();
        assert!(!s.energy_host_resolved());
    }
}
