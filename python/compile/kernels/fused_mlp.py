"""Fused dense layer as a Pallas kernel: y = act(x @ W + b).

This is the compute hot-spot of every SPARTA policy network (all five agents
are MLP or MLP+LSTM stacks). The kernel fuses the matmul, bias add and
activation into one VMEM-resident pass.

Hardware adaptation (see DESIGN.md §Hardware-Adaptation): on TPU the natural
shape is MXU 128x128 tiles, so wide layers are tiled along the output (N)
dimension with a grid, keeping one (M, K) x (K, 128) product per grid step
in VMEM. Narrow layers (policy heads, batch-1 inference) fit in a single
block. ``interpret=True`` is mandatory here: the CPU PJRT client cannot run
Mosaic custom-calls, and interpret mode lowers the kernel to plain HLO that
the Rust runtime executes.
"""

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

# Output-dimension tile, matched to the MXU lane width.
TILE_N = 128
# Tile the N dimension only when it is an exact multiple (padding is handled
# by the caller-side wrapper below).
_SINGLE_BLOCK_MAX_ELEMS = 1 << 18  # ~1 MB of f32: fits VMEM comfortably


def _make_kernel(activation):
    def kernel(x_ref, w_ref, b_ref, o_ref):
        acc = jnp.dot(x_ref[...], w_ref[...], preferred_element_type=jnp.float32)
        acc = acc + b_ref[...][None, :]
        if activation == "relu":
            acc = jnp.maximum(acc, 0.0)
        elif activation == "tanh":
            acc = jnp.tanh(acc)
        o_ref[...] = acc

    return kernel


def fused_dense(x, w, b, activation="relu"):
    """act(x @ w + b) via Pallas. x: (M, K), w: (K, N), b: (N,)."""
    if activation not in ("relu", "tanh", "linear"):
        raise ValueError(f"unknown activation {activation!r}")
    m, k = x.shape
    k2, n = w.shape
    assert k == k2 and b.shape == (n,), f"shape mismatch {x.shape} {w.shape} {b.shape}"

    kernel = _make_kernel(activation)
    single_block = (n % TILE_N != 0) or (m * k * n <= _SINGLE_BLOCK_MAX_ELEMS)
    if single_block:
        # Whole layer in one VMEM block (heads, small hidden layers,
        # batch-1 inference).
        return pl.pallas_call(
            kernel,
            out_shape=jax.ShapeDtypeStruct((m, n), jnp.float32),
            interpret=True,
        )(x, w, b)

    # Tiled along N: one (K, TILE_N) weight panel per grid step.
    grid = (n // TILE_N,)
    return pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((m, k), lambda j: (0, 0)),
            pl.BlockSpec((k, TILE_N), lambda j: (0, j)),
            pl.BlockSpec((TILE_N,), lambda j: (j,)),
        ],
        out_specs=pl.BlockSpec((m, TILE_N), lambda j: (0, j)),
        out_shape=jax.ShapeDtypeStruct((m, n), jnp.float32),
        interpret=True,
    )(x, w, b)


def vmem_estimate_bytes(m, k, n):
    """Estimated VMEM working set of one grid step, bytes (f32).

    Used by DESIGN.md / EXPERIMENTS.md §Perf to check block shapes against
    the ~16 MiB/core VMEM budget of a TPU.
    """
    n_eff = TILE_N if (n % TILE_N == 0 and m * k * n > _SINGLE_BLOCK_MAX_ELEMS) else n
    return 4 * (m * k + k * n_eff + n_eff + m * n_eff)
