"""Fused LSTM cell as a Pallas kernel.

Used by the recurrent agents (R_PPO, DRQN): one step fuses the 4-gate
projection (a single (B, I+H) x (I+H, 4H) matmul on the MXU) with the
element-wise gating (VPU) so intermediate gate tensors never leave VMEM.

``interpret=True`` is mandatory for the CPU PJRT path (see fused_mlp.py).
"""

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _kernel(x_ref, h_ref, c_ref, wih_ref, whh_ref, bih_ref, bhh_ref, h_out, c_out):
    hidden = h_ref.shape[-1]
    gates = (
        jnp.dot(x_ref[...], wih_ref[...], preferred_element_type=jnp.float32)
        + jnp.dot(h_ref[...], whh_ref[...], preferred_element_type=jnp.float32)
        + bih_ref[...][None, :]
        + bhh_ref[...][None, :]
    )
    i = jax.nn.sigmoid(gates[:, :hidden])
    f = jax.nn.sigmoid(gates[:, hidden : 2 * hidden])
    g = jnp.tanh(gates[:, 2 * hidden : 3 * hidden])
    o = jax.nn.sigmoid(gates[:, 3 * hidden :])
    c_new = f * c_ref[...] + i * g
    h_out[...] = o * jnp.tanh(c_new)
    c_out[...] = c_new


def lstm_cell(x, h, c, wih, whh, bih, bhh):
    """One LSTM step. Shapes as in ref.lstm_cell_ref. Returns (h', c')."""
    b, hidden = h.shape
    assert c.shape == (b, hidden)
    assert wih.shape == (x.shape[1], 4 * hidden)
    assert whh.shape == (hidden, 4 * hidden)
    h_new, c_new = pl.pallas_call(
        _kernel,
        out_shape=(
            jax.ShapeDtypeStruct((b, hidden), jnp.float32),
            jax.ShapeDtypeStruct((b, hidden), jnp.float32),
        ),
        interpret=True,
    )(x, h, c, wih, whh, bih, bhh)
    return h_new, c_new


def vmem_estimate_bytes(batch, inp, hidden):
    """Estimated VMEM working set, bytes (f32): inputs + weights + gates."""
    return 4 * (
        batch * inp
        + 2 * batch * hidden
        + inp * 4 * hidden
        + hidden * 4 * hidden
        + 8 * hidden
        + batch * 4 * hidden
        + 2 * batch * hidden
    )
