"""Pure-jnp reference oracles for the Pallas kernels.

These are the ground truth the kernels are validated against (pytest +
hypothesis), and they are also what the *training* graphs use: autodiff
through interpret-mode ``pallas_call`` is not guaranteed across jax versions,
so forward/inference graphs call the Pallas kernels (the request hot path)
while gradient computations run through these mathematically identical
implementations.
"""

import jax
import jax.numpy as jnp


def dense_ref(x, w, b, activation="relu"):
    """y = act(x @ w + b).

    x: (M, K), w: (K, N), b: (N,) -> (M, N).
    """
    y = jnp.dot(x, w, preferred_element_type=jnp.float32) + b[None, :]
    if activation == "relu":
        y = jnp.maximum(y, 0.0)
    elif activation == "tanh":
        y = jnp.tanh(y)
    elif activation == "linear":
        pass
    else:
        raise ValueError(f"unknown activation {activation!r}")
    return y


def lstm_cell_ref(x, h, c, wih, whh, bih, bhh):
    """One fused LSTM step. Gate order: i, f, g, o (PyTorch convention).

    x: (B, I), h/c: (B, H), wih: (I, 4H), whh: (H, 4H), biases: (4H,).
    Returns (h_new, c_new).
    """
    hidden = h.shape[-1]
    gates = x @ wih + h @ whh + bih[None, :] + bhh[None, :]
    i = jax.nn.sigmoid(gates[:, :hidden])
    f = jax.nn.sigmoid(gates[:, hidden : 2 * hidden])
    g = jnp.tanh(gates[:, 2 * hidden : 3 * hidden])
    o = jax.nn.sigmoid(gates[:, 3 * hidden :])
    c_new = f * c + i * g
    h_new = o * jnp.tanh(c_new)
    return h_new, c_new


def kmeans_assign_ref(points, centroids):
    """Nearest-centroid assignment.

    points: (N, D), centroids: (K, D) -> (N,) float32 indices.
    Distances use the expanded form |p|^2 - 2 p.c + |c|^2 so the inner
    product dominates the FLOPs (MXU-friendly on TPU).
    """
    p2 = jnp.sum(points * points, axis=1, keepdims=True)
    c2 = jnp.sum(centroids * centroids, axis=1)[None, :]
    d = p2 - 2.0 * points @ centroids.T + c2
    return jnp.argmin(d, axis=1).astype(jnp.float32)
