"""k-means nearest-centroid assignment as a Pallas kernel.

The emulated training environment (paper §3.4) clusters logged transitions
and, during training, assigns each (state, action) query to its nearest
centroid. The kernel computes all pairwise squared distances with the
expanded form so the (N, D) x (D, K) inner product runs on the MXU, then
reduces with an argmin on the VPU.

Exported standalone as the ``kmeans_assign`` artifact; the Rust emulator can
use it in place of its scalar implementation (compared in benches/micro.rs).
"""

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _kernel(p_ref, c_ref, o_ref):
    p = p_ref[...]
    c = c_ref[...]
    p2 = jnp.sum(p * p, axis=1, keepdims=True)
    c2 = jnp.sum(c * c, axis=1)[None, :]
    d = p2 - 2.0 * jnp.dot(p, c.T, preferred_element_type=jnp.float32) + c2
    o_ref[...] = jnp.argmin(d, axis=1).astype(jnp.float32)


def kmeans_assign(points, centroids):
    """points: (N, D), centroids: (K, D) -> float32 (N,) of indices."""
    n, d = points.shape
    k, d2 = centroids.shape
    assert d == d2
    return pl.pallas_call(
        _kernel,
        out_shape=jax.ShapeDtypeStruct((n,), jnp.float32),
        interpret=True,
    )(points, centroids)


def vmem_estimate_bytes(n, k, d):
    """Estimated VMEM working set, bytes (f32)."""
    return 4 * (n * d + k * d + n * k + n)
