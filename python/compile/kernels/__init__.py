"""Layer-1 Pallas kernels (build-time only; lowered into the HLO artifacts).

All kernels run with ``interpret=True``: real TPU lowering would emit Mosaic
custom-calls the CPU PJRT plugin cannot execute. Correctness is pinned to
the pure-jnp oracles in :mod:`ref` by the pytest/hypothesis suite.
"""

from .fused_mlp import fused_dense
from .kmeans import kmeans_assign
from .lstm_cell import lstm_cell
from . import ref

__all__ = ["fused_dense", "kmeans_assign", "lstm_cell", "ref"]
