"""Layer 2: the five DRL algorithms' networks and update rules as pure JAX.

Every algorithm stores ALL of its trainable tensors in one flat f32 vector
(policy + value / actor + critic together); the slice layout is exported in
the manifest so the Rust side can save/load/target-copy without knowing the
architecture. Forward (inference) graphs call the Layer-1 Pallas kernels —
they are the per-MI hot path; training graphs differentiate through the
pure-jnp oracles (same math, see kernels/ref.py).

Hyperparameters follow the paper's appendix (Tables 2-6) with two documented
CPU-budget reductions: R_PPO's LSTM hidden size 256 -> 128 and the off-policy
batch sizes 256 -> 64 (DESIGN.md §1).
"""

import math

import jax
import jax.numpy as jnp
import numpy as np

from .kernels import fused_dense, lstm_cell
from .kernels.ref import dense_ref, lstm_cell_ref

# ---------------------------------------------------------------------------
# Global state-space constants (must match rust/src/coordinator/state.rs).
# ---------------------------------------------------------------------------
WINDOW = 8      # state history length n
FEATURES = 5    # plr, rtt_gradient, rtt_ratio, cc, p
N_ACTIONS = 5
OBS = WINDOW * FEATURES
GAMMA = 0.99

# Architecture constants.
DQN_HIDDEN = [128, 128]         # Table 2
PPO_HIDDEN = [128, 128]         # Table 3 (policy and value)
DDPG_HIDDEN = [400, 300]        # Table 4
RPPO_LSTM = 128                 # Table 5 says 256; reduced for CPU budget
DRQN_DENSE = 64                 # Table 6: [64, LSTM(64)]
DRQN_LSTM = 64

# Batch sizes per training-step graph.
BATCH = {"dqn": 32, "ppo": 64, "ddpg": 64, "rppo": 64, "drqn": 64}
LR = {"dqn": 5e-4, "ppo": 3e-4, "ddpg": 1e-3, "rppo": 3e-4, "drqn": 1e-3}
MAX_GRAD_NORM = {"dqn": 10.0, "ppo": 0.5, "ddpg": 10.0, "rppo": 0.5, "drqn": 10.0}
CLIP_RANGE = 0.2
VF_COEF = 0.5
ENT_COEF = 0.01  # Table 3 uses 0.0; a small bonus prevents premature collapse
# under the sparse difference-based reward (EXPERIMENTS.md §Perf notes).


# ---------------------------------------------------------------------------
# Flat-parameter layout machinery.
# ---------------------------------------------------------------------------
class Layout:
    """Ordered (name, shape) table mapped onto one flat f32 vector."""

    def __init__(self, entries):
        self.entries = list(entries)
        self.offsets = {}
        off = 0
        for name, shape in self.entries:
            size = int(np.prod(shape)) if shape else 1
            self.offsets[name] = (off, shape)
            off += size
        self.size = off

    def slice(self, flat, name):
        off, shape = self.offsets[name]
        size = int(np.prod(shape)) if shape else 1
        return flat[off : off + size].reshape(shape)

    def unflatten(self, flat):
        return {name: self.slice(flat, name) for name, _ in self.entries}

    def mask(self, prefix):
        """0/1 vector selecting all entries whose name starts with prefix."""
        m = np.zeros(self.size, np.float32)
        for name, shape in self.entries:
            if name.startswith(prefix):
                off, _ = self.offsets[name]
                size = int(np.prod(shape)) if shape else 1
                m[off : off + size] = 1.0
        return jnp.asarray(m)

    def init(self, rng):
        """Glorot-uniform weights, zero biases, as one flat numpy vector."""
        flat = np.zeros(self.size, np.float32)
        for name, shape in self.entries:
            off, _ = self.offsets[name]
            size = int(np.prod(shape)) if shape else 1
            if len(shape) == 2:
                fan_in, fan_out = shape
                lim = math.sqrt(6.0 / (fan_in + fan_out))
                flat[off : off + size] = rng.uniform(-lim, lim, size).astype(np.float32)
            # biases stay zero; LSTM forget-gate bias boosted below
        return flat


def mlp_layout(prefix, sizes):
    """[(f"{prefix}.w0", (in, h0)), (f"{prefix}.b0", (h0,)), ...]"""
    entries = []
    for i, (a, b) in enumerate(zip(sizes[:-1], sizes[1:])):
        entries.append((f"{prefix}.w{i}", (a, b)))
        entries.append((f"{prefix}.b{i}", (b,)))
    return entries


def lstm_layout(prefix, inp, hidden):
    return [
        (f"{prefix}.wih", (inp, 4 * hidden)),
        (f"{prefix}.whh", (hidden, 4 * hidden)),
        (f"{prefix}.bih", (4 * hidden,)),
        (f"{prefix}.bhh", (4 * hidden,)),
    ]


def mlp_apply(layout, flat, prefix, x, n_layers, dense=dense_ref, out_act="linear"):
    """Apply an MLP; hidden layers ReLU, final layer `out_act`."""
    for i in range(n_layers):
        w = layout.slice(flat, f"{prefix}.w{i}")
        b = layout.slice(flat, f"{prefix}.b{i}")
        act = out_act if i == n_layers - 1 else "relu"
        x = dense(x, w, b, act)
    return x


def lstm_scan(layout, flat, prefix, xs, hidden, cell=lstm_cell_ref):
    """Run an LSTM over time. xs: (T, B, I) -> final hidden (B, H)."""
    wih = layout.slice(flat, f"{prefix}.wih")
    whh = layout.slice(flat, f"{prefix}.whh")
    bih = layout.slice(flat, f"{prefix}.bih")
    bhh = layout.slice(flat, f"{prefix}.bhh")
    b = xs.shape[1]
    h0 = jnp.zeros((b, hidden), jnp.float32)
    c0 = jnp.zeros((b, hidden), jnp.float32)

    def step(carry, x):
        h, c = carry
        h, c = cell(x, h, c, wih, whh, bih, bhh)
        return (h, c), None

    (h, _c), _ = jax.lax.scan(step, (h0, c0), xs)
    return h


# ---------------------------------------------------------------------------
# Per-algorithm layouts.
# ---------------------------------------------------------------------------
LAYOUTS = {
    "dqn": Layout(mlp_layout("q", [OBS] + DQN_HIDDEN + [N_ACTIONS])),
    "ppo": Layout(
        mlp_layout("pi", [OBS] + PPO_HIDDEN + [N_ACTIONS])
        + mlp_layout("vf", [OBS] + PPO_HIDDEN + [1])
    ),
    "ddpg": Layout(
        mlp_layout("actor", [OBS] + DDPG_HIDDEN + [2])
        + mlp_layout("critic", [OBS + 2] + DDPG_HIDDEN + [1])
    ),
    "rppo": Layout(
        lstm_layout("pi_lstm", FEATURES, RPPO_LSTM)
        + mlp_layout("pi", [RPPO_LSTM, N_ACTIONS])
        + lstm_layout("vf_lstm", FEATURES, RPPO_LSTM)
        + mlp_layout("vf", [RPPO_LSTM, 1])
    ),
    "drqn": Layout(
        mlp_layout("enc", [FEATURES, DRQN_DENSE])
        + lstm_layout("lstm", DRQN_DENSE, DRQN_LSTM)
        + mlp_layout("q", [DRQN_LSTM, N_ACTIONS])
    ),
}


def init_params(algo, seed=0):
    rng = np.random.RandomState(seed)
    layout = LAYOUTS[algo]
    flat = layout.init(rng)
    # LSTM forget-gate bias = 1 (standard trick for gradient flow).
    for name, shape in layout.entries:
        if name.endswith(".bih"):
            off, _ = layout.offsets[name]
            hidden = shape[0] // 4
            flat[off + hidden : off + 2 * hidden] = 1.0
    return flat


# ---------------------------------------------------------------------------
# Forward (inference) graphs — batch-1, Pallas kernels on the hot path.
# ---------------------------------------------------------------------------
def dqn_forward(flat, obs):
    """obs: (OBS,) -> (q[N_ACTIONS],)"""
    q = mlp_apply(LAYOUTS["dqn"], flat, "q", obs[None, :], 3, dense=fused_dense)
    return (q[0],)


def ppo_forward(flat, obs):
    """obs: (OBS,) -> (logits[N_ACTIONS], value[1])"""
    lo = LAYOUTS["ppo"]
    x = obs[None, :]
    logits = mlp_apply(lo, flat, "pi", x, 3, dense=fused_dense)
    value = mlp_apply(lo, flat, "vf", x, 3, dense=fused_dense)
    return (logits[0], value[0])


def ddpg_forward(flat, obs):
    """obs: (OBS,) -> (action[2] in [-2, 2]^2,)"""
    a = mlp_apply(LAYOUTS["ddpg"], flat, "actor", obs[None, :], 3,
                  dense=fused_dense, out_act="tanh")
    return (2.0 * a[0],)


def rppo_forward(flat, obs):
    """obs: (WINDOW, FEATURES) -> (logits[N_ACTIONS], value[1])"""
    lo = LAYOUTS["rppo"]
    xs = obs[:, None, :]  # (T, B=1, F)
    h_pi = lstm_scan(lo, flat, "pi_lstm", xs, RPPO_LSTM, cell=lstm_cell)
    h_vf = lstm_scan(lo, flat, "vf_lstm", xs, RPPO_LSTM, cell=lstm_cell)
    logits = mlp_apply(lo, flat, "pi", h_pi, 1, dense=fused_dense)
    value = mlp_apply(lo, flat, "vf", h_vf, 1, dense=fused_dense)
    return (logits[0], value[0])


def drqn_forward(flat, obs):
    """obs: (WINDOW, FEATURES) -> (q[N_ACTIONS],)"""
    lo = LAYOUTS["drqn"]
    xs = obs[:, None, :]
    enc = jax.vmap(lambda x: mlp_apply(lo, flat, "enc", x, 1, dense=dense_ref, out_act="relu"))(xs)
    h = lstm_scan(lo, flat, "lstm", enc, DRQN_LSTM, cell=lstm_cell)
    q = mlp_apply(lo, flat, "q", h, 1, dense=fused_dense)
    return (q[0],)


# Batched (ref-kernel) forwards used inside the training losses.
def _dqn_q(flat, obs_b):
    return mlp_apply(LAYOUTS["dqn"], flat, "q", obs_b, 3)


def _ppo_pi_vf(flat, obs_b):
    lo = LAYOUTS["ppo"]
    return (
        mlp_apply(lo, flat, "pi", obs_b, 3),
        mlp_apply(lo, flat, "vf", obs_b, 3)[:, 0],
    )


def _ddpg_actor(flat, obs_b):
    a = mlp_apply(LAYOUTS["ddpg"], flat, "actor", obs_b, 3, out_act="tanh")
    return 2.0 * a


def _ddpg_critic(flat, obs_b, act_b):
    x = jnp.concatenate([obs_b, act_b], axis=1)
    return mlp_apply(LAYOUTS["ddpg"], flat, "critic", x, 3)[:, 0]


def _rppo_pi_vf(flat, obs_b):
    """obs_b: (B, WINDOW, FEATURES)."""
    lo = LAYOUTS["rppo"]
    xs = jnp.transpose(obs_b, (1, 0, 2))  # (T, B, F)
    h_pi = lstm_scan(lo, flat, "pi_lstm", xs, RPPO_LSTM)
    h_vf = lstm_scan(lo, flat, "vf_lstm", xs, RPPO_LSTM)
    logits = mlp_apply(lo, flat, "pi", h_pi, 1)
    value = mlp_apply(lo, flat, "vf", h_vf, 1)[:, 0]
    return logits, value


def _drqn_q(flat, obs_b):
    lo = LAYOUTS["drqn"]
    xs = jnp.transpose(obs_b, (1, 0, 2))
    t, b, f = xs.shape
    enc = mlp_apply(lo, flat, "enc", xs.reshape(t * b, f), 1, out_act="relu").reshape(t, b, -1)
    h = lstm_scan(lo, flat, "lstm", enc, DRQN_LSTM)
    return mlp_apply(lo, flat, "q", h, 1)


# ---------------------------------------------------------------------------
# Adam with global-norm clipping (optimizer state threads through the graph).
# ---------------------------------------------------------------------------
def adam(flat, m, v, step, grad, lr, max_norm):
    norm = jnp.sqrt(jnp.sum(grad * grad) + 1e-12)
    grad = grad * jnp.minimum(1.0, max_norm / norm)
    m = 0.9 * m + 0.1 * grad
    v = 0.999 * v + 0.001 * grad * grad
    mh = m / (1.0 - jnp.power(0.9, step))
    vh = v / (1.0 - jnp.power(0.999, step))
    flat = flat - lr * mh / (jnp.sqrt(vh) + 1e-8)
    return flat, m, v


def _huber(x):
    a = jnp.abs(x)
    return jnp.where(a <= 1.0, 0.5 * x * x, a - 0.5)


# ---------------------------------------------------------------------------
# Training-step graphs (one Adam minibatch update each).
# ---------------------------------------------------------------------------
def _td_train(q_fn, algo):
    """Shared DQN/DRQN TD(0) update with a frozen target network."""

    def train(flat, tflat, m, v, step, obs, act, rew, nobs, done):
        def loss_fn(p):
            q = q_fn(p, obs)
            qa = jnp.sum(q * jax.nn.one_hot(act.astype(jnp.int32), N_ACTIONS), axis=1)
            tq = jnp.max(q_fn(tflat, nobs), axis=1)
            target = rew + GAMMA * (1.0 - done) * jax.lax.stop_gradient(tq)
            return jnp.mean(_huber(qa - target))

        loss, grad = jax.value_and_grad(loss_fn)(flat)
        flat2, m2, v2 = adam(flat, m, v, step, grad, LR[algo], MAX_GRAD_NORM[algo])
        return (flat2, m2, v2, loss[None])

    return train


dqn_train = _td_train(_dqn_q, "dqn")
drqn_train = _td_train(_drqn_q, "drqn")


def _ppo_train(pi_vf_fn, algo):
    """Shared PPO/R_PPO clipped-surrogate update (Table 3/5)."""

    def train(flat, m, v, step, obs, act, old_logp, adv, ret):
        def loss_fn(p):
            logits, values = pi_vf_fn(p, obs)
            logp_all = jax.nn.log_softmax(logits)
            logp = jnp.sum(logp_all * jax.nn.one_hot(act.astype(jnp.int32), N_ACTIONS), axis=1)
            # Normalize advantages (Table 3: normalize_advantage = true).
            a = (adv - jnp.mean(adv)) / (jnp.std(adv) + 1e-8)
            ratio = jnp.exp(logp - old_logp)
            surr = jnp.minimum(ratio * a, jnp.clip(ratio, 1.0 - CLIP_RANGE, 1.0 + CLIP_RANGE) * a)
            vf = jnp.mean((values - ret) ** 2)
            ent = -jnp.mean(jnp.sum(jnp.exp(logp_all) * logp_all, axis=1))
            return -jnp.mean(surr) + VF_COEF * vf - ENT_COEF * ent

        loss, grad = jax.value_and_grad(loss_fn)(flat)
        flat2, m2, v2 = adam(flat, m, v, step, grad, LR[algo], MAX_GRAD_NORM[algo])
        return (flat2, m2, v2, loss[None])

    return train


ppo_train = _ppo_train(_ppo_pi_vf, "ppo")
rppo_train = _ppo_train(_rppo_pi_vf, "rppo")


def ddpg_train(flat, tflat, m, v, step, obs, act, rew, nobs, done):
    """DDPG actor-critic update (Table 4); soft target updates are done on
    the Rust side (tau = 0.005 vector lerp over the flat params)."""
    lo = LAYOUTS["ddpg"]
    actor_mask = lo.mask("actor")

    def critic_loss_fn(p):
        q = _ddpg_critic(p, obs, act)
        na = _ddpg_actor(tflat, nobs)
        tq = _ddpg_critic(tflat, nobs, na)
        target = rew + GAMMA * (1.0 - done) * jax.lax.stop_gradient(tq)
        return jnp.mean((q - target) ** 2)

    def actor_loss_fn(p):
        # Deterministic policy gradient: -mean Q(s, pi(s)). Gradients w.r.t.
        # the critic slice are discarded by the mask below, so the critic is
        # effectively frozen for this term.
        a = _ddpg_actor(p, obs)
        return -jnp.mean(_ddpg_critic(p, obs, a))

    closs, cgrad = jax.value_and_grad(critic_loss_fn)(flat)
    aloss, agrad = jax.value_and_grad(actor_loss_fn)(flat)
    grad = cgrad * (1.0 - actor_mask) + agrad * actor_mask
    flat2, m2, v2 = adam(flat, m, v, step, grad, LR["ddpg"], MAX_GRAD_NORM["ddpg"])
    return (flat2, m2, v2, aloss[None], closs[None])
