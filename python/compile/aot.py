"""AOT exporter: lower every graph to HLO text + manifest + init params.

HLO *text* is the interchange format (NOT ``lowered.compiler_ir("hlo")`` /
``.serialize()``): jax >= 0.5 emits HloModuleProtos with 64-bit instruction
ids that the image's xla_extension 0.5.1 rejects (``proto.id() <= INT_MAX``);
the text parser reassigns ids and round-trips cleanly. See
/opt/xla-example/README.md.

Usage: ``python -m compile.aot --out ../artifacts`` (idempotent; `make
artifacts` wires this up).
"""

import argparse
import json
import os

import jax
import jax.numpy as jnp
import numpy as np
from jax._src.lib import xla_client as xc

from . import model as M
from .kernels import kmeans_assign

# Fixed shapes for the standalone k-means assignment artifact; the Rust
# emulator pads its query batch to these.
KMEANS_N = 1024
KMEANS_K = 64
KMEANS_D = M.FEATURES + 1


def to_hlo_text(fn, example_args):
    """jit-lower `fn` and convert to XLA HLO text via stablehlo."""
    lowered = jax.jit(fn).lower(*example_args)
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    return comp.as_hlo_text()


def spec(*dims):
    return jax.ShapeDtypeStruct(tuple(dims), jnp.float32)


def graph_table():
    """name -> (fn, arg_names, example_args, n_outputs)."""
    g = {}
    for algo in ("dqn", "drqn", "ppo", "rppo", "ddpg"):
        n = M.LAYOUTS[algo].size
        b = M.BATCH[algo]
        obs1 = spec(M.OBS) if algo in ("dqn", "ppo", "ddpg") else spec(M.WINDOW, M.FEATURES)
        obsb = spec(b, M.OBS) if algo in ("dqn", "ppo", "ddpg") else spec(b, M.WINDOW, M.FEATURES)
        fwd = getattr(M, f"{algo}_forward")
        n_fwd_out = {"dqn": 1, "drqn": 1, "ppo": 2, "rppo": 2, "ddpg": 1}[algo]
        g[f"{algo}_forward"] = (fwd, ["params", "obs"], [spec(n), obs1], n_fwd_out)

        train = getattr(M, f"{algo}_train")
        if algo in ("dqn", "drqn"):
            g[f"{algo}_train"] = (
                train,
                ["params", "tparams", "m", "v", "step", "obs", "act", "rew", "next_obs", "done"],
                [spec(n), spec(n), spec(n), spec(n), spec(), obsb, spec(b), spec(b), obsb, spec(b)],
                4,
            )
        elif algo in ("ppo", "rppo"):
            g[f"{algo}_train"] = (
                train,
                ["params", "m", "v", "step", "obs", "act", "old_logp", "adv", "ret"],
                [spec(n), spec(n), spec(n), spec(), obsb, spec(b), spec(b), spec(b), spec(b)],
                4,
            )
        else:  # ddpg
            g[f"{algo}_train"] = (
                train,
                ["params", "tparams", "m", "v", "step", "obs", "act", "rew", "next_obs", "done"],
                [spec(n), spec(n), spec(n), spec(n), spec(), obsb, spec(b, 2), spec(b), obsb, spec(b)],
                5,
            )
    g["kmeans_assign"] = (
        lambda pts, cen: (kmeans_assign(pts, cen),),
        ["points", "centroids"],
        [spec(KMEANS_N, KMEANS_D), spec(KMEANS_K, KMEANS_D)],
        1,
    )
    return g


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--out", default="../artifacts", help="artifacts directory")
    ap.add_argument("--only", default=None, help="export a single graph (debug)")
    args = ap.parse_args()
    os.makedirs(args.out, exist_ok=True)

    graphs = {}
    table = graph_table()
    for name, (fn, arg_names, example, n_out) in sorted(table.items()):
        if args.only and name != args.only:
            continue
        text = to_hlo_text(fn, example)
        fname = f"{name}.hlo.txt"
        with open(os.path.join(args.out, fname), "w") as f:
            f.write(text)
        graphs[name] = {
            "file": fname,
            "arg_names": arg_names,
            "arg_shapes": [list(a.shape) for a in example],
            "n_outputs": n_out,
        }
        print(f"  {name}: {len(text)} chars, args={arg_names}")

    algos = {}
    for algo in ("dqn", "drqn", "ppo", "rppo", "ddpg"):
        flat = M.init_params(algo, seed=42)
        flat.tofile(os.path.join(args.out, f"{algo}_init.f32"))
        algos[algo] = {
            "n_params": int(M.LAYOUTS[algo].size),
            "hparams": {
                "gamma": M.GAMMA,
                "lr": M.LR[algo],
                "batch": M.BATCH[algo],
                "max_grad_norm": M.MAX_GRAD_NORM[algo],
                "clip_range": M.CLIP_RANGE,
            },
            "graphs": [f"{algo}_forward", f"{algo}_train"],
        }

    manifest = {
        "graphs": graphs,
        "algos": algos,
        "globals": {
            "window": M.WINDOW,
            "features": M.FEATURES,
            "n_actions": M.N_ACTIONS,
            "kmeans_n": KMEANS_N,
            "kmeans_k": KMEANS_K,
            "kmeans_d": KMEANS_D,
        },
    }
    with open(os.path.join(args.out, "manifest.json"), "w") as f:
        json.dump(manifest, f, indent=1)
    print(f"wrote manifest with {len(graphs)} graphs to {args.out}/manifest.json")


if __name__ == "__main__":
    main()
