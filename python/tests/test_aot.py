"""AOT export contract: manifest consistency and HLO-text validity."""

import json
import os

import numpy as np
import pytest

from compile import aot, model as M

ART = os.path.join(os.path.dirname(__file__), "..", "..", "artifacts")


@pytest.fixture(scope="module")
def manifest():
    path = os.path.join(ART, "manifest.json")
    if not os.path.exists(path):
        pytest.skip("artifacts not built (run `make artifacts`)")
    with open(path) as f:
        return json.load(f)


def test_graph_table_covers_all_algos():
    table = aot.graph_table()
    for algo in M.LAYOUTS:
        assert f"{algo}_forward" in table
        assert f"{algo}_train" in table
    assert "kmeans_assign" in table


def test_manifest_matches_layouts(manifest):
    for algo, lo in M.LAYOUTS.items():
        assert manifest["algos"][algo]["n_params"] == lo.size
    g = manifest["globals"]
    assert g["window"] == M.WINDOW
    assert g["features"] == M.FEATURES
    assert g["n_actions"] == M.N_ACTIONS


def test_manifest_arg_shapes_match_table(manifest):
    table = aot.graph_table()
    for name, (fn, arg_names, example, n_out) in table.items():
        entry = manifest["graphs"][name]
        assert entry["arg_names"] == arg_names
        assert entry["arg_shapes"] == [list(a.shape) for a in example]
        assert entry["n_outputs"] == n_out


def test_init_params_files_match_sizes(manifest):
    for algo, spec in manifest["algos"].items():
        path = os.path.join(ART, f"{algo}_init.f32")
        data = np.fromfile(path, dtype=np.float32)
        assert len(data) == spec["n_params"]
        assert np.all(np.isfinite(data))


def test_hlo_text_files_parse_as_hlo(manifest):
    for name, entry in manifest["graphs"].items():
        path = os.path.join(ART, entry["file"])
        with open(path) as f:
            text = f.read()
        assert text.startswith("HloModule"), f"{name} not HLO text"
        # ENTRY computation present with a tuple root (return_tuple=True).
        assert "ENTRY" in text


def test_hlo_text_is_deterministic(tmp_path):
    # Re-lowering the same graph yields identical text (reproducible builds).
    table = aot.graph_table()
    fn, _, example, _ = table["dqn_forward"]
    a = aot.to_hlo_text(fn, example)
    b = aot.to_hlo_text(fn, example)
    assert a == b
