"""L1 correctness: Pallas kernels vs pure-jnp oracles.

Hypothesis sweeps shapes and value ranges; every kernel must match its
oracle to float32 tolerance across the sweep.
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from compile.kernels import fused_dense, kmeans_assign, lstm_cell, ref

jax.config.update("jax_platform_name", "cpu")

dims = st.integers(min_value=1, max_value=48)
small = st.floats(min_value=-3.0, max_value=3.0, allow_nan=False, width=32)


def arr(rng, *shape, scale=1.0):
    return jnp.asarray(rng.standard_normal(shape).astype(np.float32) * scale)


@settings(max_examples=25, deadline=None)
@given(m=dims, k=dims, n=dims, act=st.sampled_from(["relu", "tanh", "linear"]), seed=st.integers(0, 2**31 - 1))
def test_fused_dense_matches_ref(m, k, n, act, seed):
    rng = np.random.default_rng(seed)
    x, w, b = arr(rng, m, k), arr(rng, k, n), arr(rng, n)
    got = fused_dense(x, w, b, act)
    want = ref.dense_ref(x, w, b, act)
    np.testing.assert_allclose(got, want, atol=1e-4, rtol=1e-4)


@pytest.mark.parametrize("m,k,n", [(1, 40, 128), (64, 128, 128), (64, 400, 256), (2, 402, 384)])
def test_fused_dense_tiled_and_single_block_paths(m, k, n):
    rng = np.random.default_rng(1)
    x, w, b = arr(rng, m, k), arr(rng, k, n), arr(rng, n)
    np.testing.assert_allclose(fused_dense(x, w, b), ref.dense_ref(x, w, b), atol=1e-3, rtol=1e-4)


def test_fused_dense_rejects_unknown_activation():
    rng = np.random.default_rng(0)
    with pytest.raises(ValueError):
        fused_dense(arr(rng, 2, 3), arr(rng, 3, 4), arr(rng, 4), "gelu")


@settings(max_examples=20, deadline=None)
@given(b=st.integers(1, 16), i=st.integers(1, 40), h=st.integers(1, 64), seed=st.integers(0, 2**31 - 1))
def test_lstm_cell_matches_ref(b, i, h, seed):
    rng = np.random.default_rng(seed)
    x = arr(rng, b, i)
    hh = arr(rng, b, h, scale=0.5)
    cc = arr(rng, b, h, scale=0.5)
    wih = arr(rng, i, 4 * h, scale=0.2)
    whh = arr(rng, h, 4 * h, scale=0.2)
    bih = arr(rng, 4 * h, scale=0.1)
    bhh = arr(rng, 4 * h, scale=0.1)
    h1, c1 = lstm_cell(x, hh, cc, wih, whh, bih, bhh)
    h2, c2 = ref.lstm_cell_ref(x, hh, cc, wih, whh, bih, bhh)
    np.testing.assert_allclose(h1, h2, atol=1e-5, rtol=1e-5)
    np.testing.assert_allclose(c1, c2, atol=1e-5, rtol=1e-5)


def test_lstm_cell_state_bounded():
    # |h| <= 1 by construction (o * tanh(c)).
    rng = np.random.default_rng(3)
    h, c = lstm_cell(
        arr(rng, 4, 8, scale=10), arr(rng, 4, 16), arr(rng, 4, 16),
        arr(rng, 8, 64, scale=5), arr(rng, 16, 64, scale=5),
        arr(rng, 64), arr(rng, 64),
    )
    assert np.all(np.abs(h) <= 1.0 + 1e-6)


@settings(max_examples=20, deadline=None)
@given(n=st.integers(1, 200), k=st.integers(1, 32), d=st.integers(1, 12), seed=st.integers(0, 2**31 - 1))
def test_kmeans_assign_matches_ref(n, k, d, seed):
    rng = np.random.default_rng(seed)
    pts, cen = arr(rng, n, d), arr(rng, k, d)
    np.testing.assert_array_equal(kmeans_assign(pts, cen), ref.kmeans_assign_ref(pts, cen))


def test_kmeans_assign_identifies_own_centroid():
    # Distinct centroids: each point nearest to itself.
    cen = jnp.eye(8, 8, dtype=jnp.float32) * 5.0
    got = kmeans_assign(cen, cen)
    np.testing.assert_array_equal(got, np.arange(8, dtype=np.float32))
