"""L2 correctness: layouts, forwards, training-step behaviour."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from compile import model as M

jax.config.update("jax_platform_name", "cpu")


def flat(algo, seed=0):
    return jnp.asarray(M.init_params(algo, seed))


class TestLayout:
    def test_sizes_are_consistent(self):
        for algo, lo in M.LAYOUTS.items():
            total = sum(int(np.prod(s)) if s else 1 for _, s in lo.entries)
            assert lo.size == total, algo

    def test_unflatten_roundtrip(self):
        lo = M.LAYOUTS["dqn"]
        v = jnp.arange(lo.size, dtype=jnp.float32)
        d = lo.unflatten(v)
        # Reassemble in entry order and compare.
        back = jnp.concatenate([d[name].reshape(-1) for name, _ in lo.entries])
        np.testing.assert_array_equal(back, v)

    def test_mask_selects_prefix(self):
        lo = M.LAYOUTS["ddpg"]
        am = np.asarray(lo.mask("actor"))
        cm = np.asarray(lo.mask("critic"))
        assert am.sum() + cm.sum() == lo.size
        assert np.all(am * cm == 0)

    def test_forget_gate_bias_initialized(self):
        lo = M.LAYOUTS["rppo"]
        flat_p = M.init_params("rppo")
        d = lo.unflatten(jnp.asarray(flat_p))
        bih = np.asarray(d["pi_lstm.bih"])
        h = len(bih) // 4
        assert np.all(bih[h:2 * h] == 1.0)


class TestForward:
    @pytest.mark.parametrize("algo,n_out", [("dqn", 5), ("drqn", 5), ("ppo", 5), ("rppo", 5)])
    def test_heads_have_action_arity(self, algo, n_out):
        fwd = getattr(M, f"{algo}_forward")
        obs = jnp.zeros(M.OBS) if algo in ("dqn", "ppo") else jnp.zeros((M.WINDOW, M.FEATURES))
        out = fwd(flat(algo), obs)
        assert out[0].shape == (n_out,)

    def test_ddpg_actor_bounded(self):
        rng = np.random.default_rng(0)
        for _ in range(10):
            obs = jnp.asarray(rng.standard_normal(M.OBS).astype(np.float32) * 3)
            (a,) = M.ddpg_forward(flat("ddpg"), obs)
            assert np.all(np.abs(np.asarray(a)) <= 2.0 + 1e-6)

    def test_forward_deterministic(self):
        obs = jnp.full(M.OBS, 0.3)
        q1 = M.dqn_forward(flat("dqn"), obs)[0]
        q2 = M.dqn_forward(flat("dqn"), obs)[0]
        np.testing.assert_array_equal(q1, q2)

    def test_pallas_forward_matches_ref_forward(self):
        # The inference path (Pallas) and training path (ref) must agree.
        p = flat("dqn")
        rng = np.random.default_rng(7)
        obs = jnp.asarray(rng.standard_normal(M.OBS).astype(np.float32))
        q_pallas = M.dqn_forward(p, obs)[0]
        q_ref = M._dqn_q(p, obs[None, :])[0]
        np.testing.assert_allclose(q_pallas, q_ref, atol=1e-4, rtol=1e-4)

    def test_rppo_pallas_vs_ref(self):
        p = flat("rppo")
        rng = np.random.default_rng(8)
        obs = jnp.asarray(rng.standard_normal((M.WINDOW, M.FEATURES)).astype(np.float32))
        logits_pl, value_pl = M.rppo_forward(p, obs)
        logits_ref, value_ref = M._rppo_pi_vf(p, obs[None, :, :])
        np.testing.assert_allclose(logits_pl, logits_ref[0], atol=1e-4, rtol=1e-4)
        np.testing.assert_allclose(value_pl[0], value_ref[0], atol=1e-4, rtol=1e-4)


class TestTraining:
    def _batch(self, algo, seed=0):
        rng = np.random.default_rng(seed)
        b = M.BATCH[algo]
        if algo in ("dqn", "ppo", "ddpg"):
            obs = rng.standard_normal((b, M.OBS)).astype(np.float32)
        else:
            obs = rng.standard_normal((b, M.WINDOW, M.FEATURES)).astype(np.float32)
        return jnp.asarray(obs), rng

    @pytest.mark.parametrize("algo", ["dqn", "drqn"])
    def test_td_loss_decreases(self, algo):
        obs, rng = self._batch(algo)
        b = M.BATCH[algo]
        act = jnp.asarray(rng.integers(0, 5, b).astype(np.float32))
        rew = jnp.ones(b)
        done = jnp.ones(b)  # terminal: fixed target
        p = flat(algo)
        t = p
        m = jnp.zeros_like(p)
        v = jnp.zeros_like(p)
        train = getattr(M, f"{algo}_train")
        losses = []
        for step in range(1, 31):
            p, m, v, loss = train(p, t, m, v, jnp.float32(step), obs, act, rew, obs, done)
            losses.append(float(loss[0]))
        assert losses[-1] < losses[0] * 0.7, losses[:3] + losses[-3:]

    @pytest.mark.parametrize("algo", ["ppo", "rppo"])
    def test_ppo_surrogate_improves_good_action_prob(self, algo):
        obs, rng = self._batch(algo)
        b = M.BATCH[algo]
        # Mixed actions; action 1 advantageous, others not. (A constant
        # advantage vector would be zeroed by advantage normalization.)
        act = jnp.asarray(rng.integers(0, 5, b).astype(np.float32))
        adv = jnp.where(act == 1, 1.0, -1.0)
        old_logp = jnp.full(b, -np.log(5.0))
        ret = jnp.zeros(b)
        p = flat(algo)
        m = jnp.zeros_like(p)
        v = jnp.zeros_like(p)
        train = getattr(M, f"{algo}_train")
        fwd = M._ppo_pi_vf if algo == "ppo" else M._rppo_pi_vf
        before = jax.nn.softmax(fwd(p, obs)[0], axis=1)[:, 1].mean()
        for step in range(1, 11):
            p, m, v, _ = train(p, m, v, jnp.float32(step), obs, act, old_logp, adv, ret)
        after = jax.nn.softmax(fwd(p, obs)[0], axis=1)[:, 1].mean()
        assert after > before, (before, after)

    def test_ddpg_updates_both_networks(self):
        obs, rng = self._batch("ddpg")
        b = M.BATCH["ddpg"]
        act = jnp.asarray(rng.uniform(-2, 2, (b, 2)).astype(np.float32))
        rew = jnp.ones(b)
        done = jnp.zeros(b)
        p = flat("ddpg")
        out = M.ddpg_train(p, p, jnp.zeros_like(p), jnp.zeros_like(p), jnp.float32(1), obs, act, rew, obs, done)
        delta = np.abs(np.asarray(out[0] - p))
        lo = M.LAYOUTS["ddpg"]
        am = np.asarray(lo.mask("actor"))
        assert delta[am > 0].sum() > 0
        assert delta[am == 0].sum() > 0

    def test_adam_grad_clipping(self):
        g = jnp.full(10, 1e6)
        p, m, v = M.adam(jnp.zeros(10), jnp.zeros(10), jnp.zeros(10), jnp.float32(1), g, 0.001, 1.0)
        # Clipped to norm 1 -> bounded first step.
        assert np.all(np.abs(np.asarray(p)) < 0.01)
